//! Log₂-bucketed padded slab layout (paper §6 "Batched projection
//! operator").
//!
//! Sources are grouped by slice length (degree) into geometric buckets
//! `[2^{t-1}, 2^t)`; each bucket's slices are gathered into a dense slab
//! padded to the bucket's upper bound. One batched kernel launch per bucket
//! replaces one launch per source, while geometric bucketing bounds padding
//! waste below 2× — the number of launches is `1 + ⌊log₂ s_max⌋`.
//!
//! The slab row order remembers its source ids so the coordinator can
//! gather λ into per-edge `u` and scatter-add `a ⊙ x` back into the dual
//! gradient.
//!
//! On top of the buckets sits the **fixed chunk grid**
//! ([`SlabLayout::fixed_chunk_grid`]): every bucket's rows cut into
//! [`SlabChunk`] row ranges by a rule that depends on the layout alone —
//! never on thread or shard counts. The grid is the shared unit of both
//! intra-process parallelism (`backend::slab_cpu`) and cross-shard
//! partitioning (`backend::sharded`, `distributed::worker`): shards own
//! contiguous chunk ranges, so merging per-chunk partial reductions in
//! ascending chunk index reproduces the exact f32 summation order of a
//! single-shard evaluation, making sharded solves bit-identical to
//! unsharded ones.

use super::blocked::BlockedMatrix;
use crate::projection::ProjectionKind;

/// Minimum slab width (tiny rows are padded up to this).
pub const MIN_WIDTH: usize = 4;
/// Maximum slab width supported by the AOT artifact family.
pub const MAX_WIDTH: usize = 512;

/// Target size of the fixed chunk grid. Fixed (never derived from thread
/// or shard counts) so the chunk-ordered reduction — and therefore every
/// bit of the result — is identical at any pool width and shard count.
/// Chunks never span buckets, so the actual grid can exceed this by up to
/// one chunk per bucket.
pub const MAX_CHUNKS: usize = 32;
/// Minimum rows per chunk — below this the per-chunk bookkeeping
/// dominates the math.
pub const MIN_CHUNK_ROWS: usize = 64;

/// One unit of the fixed parallel/shard grid: a row range within one
/// bucket. Chunks never span buckets, so each chunk projects with one
/// operator at one width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlabChunk {
    /// Index into [`SlabLayout::buckets`].
    pub bucket: usize,
    /// First row (inclusive) of the range within the bucket.
    pub row_lo: usize,
    /// Last row (exclusive) of the range within the bucket.
    pub row_hi: usize,
}

impl SlabChunk {
    pub fn rows(&self) -> usize {
        self.row_hi - self.row_lo
    }
}

/// One log₂ bucket: a dense `[rows × width]` slab of edges.
#[derive(Clone, Debug)]
pub struct Bucket {
    /// Projection kind for every row in this bucket.
    pub kind: ProjectionKind,
    /// Padded width (power of two in [MIN_WIDTH, MAX_WIDTH]).
    pub width: usize,
    /// Source id of each row.
    pub sources: Vec<u32>,
    /// Flattened [rows × width] destination index (0 on padding).
    pub dest_idx: Vec<u32>,
    /// Flattened [rows × width] global edge index (u32::MAX on padding) —
    /// lets the coordinator apply global constraint rows and recover the
    /// per-edge primal without re-deriving chunk offsets.
    pub edge_id: Vec<u32>,
    /// Flattened [rows × width] cost coefficients (0 on padding).
    pub cost: Vec<f32>,
    /// Per-family flattened [rows × width] constraint coefficients.
    pub a: Vec<Vec<f32>>,
    /// Flattened [rows × width] validity mask (1 real, 0 padding).
    pub mask: Vec<f32>,
    /// Number of real (non-padding) edges, counted once at build time so
    /// per-iteration consumers don't rescan the mask.
    pub real_edge_count: usize,
}

impl Bucket {
    pub fn rows(&self) -> usize {
        self.sources.len()
    }

    pub fn real_edges(&self) -> usize {
        self.real_edge_count
    }

    pub fn padded_edges(&self) -> usize {
        self.dest_idx.len()
    }
}

/// The full bucketed layout of one (shard of a) matching LP.
#[derive(Clone, Debug)]
pub struct SlabLayout {
    pub buckets: Vec<Bucket>,
    pub num_families: usize,
    pub num_dests: usize,
}

/// Round degree up to the bucket width: next power of two, clamped to
/// [MIN_WIDTH, MAX_WIDTH].
pub fn bucket_width(degree: usize) -> usize {
    degree.next_power_of_two().clamp(MIN_WIDTH, MAX_WIDTH)
}

/// How an edge insert/delete was absorbed by [`SlabLayout::patch_edge`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePatch {
    /// The edited source stayed in its bucket row — padding headroom
    /// absorbed the edit and only that row was rewritten. Row counts are
    /// unchanged, so an existing chunk grid remains valid.
    InPlace,
    /// The edit moved the source across buckets (width transition, bucket
    /// creation/removal, or a split source): the affected buckets were
    /// repacked. Row counts may have changed — recompute the chunk grid.
    Repacked,
}

/// Tally of delta operations applied to a resident layout (serve-path
/// diagnostics: the in-place / repack ratio is the headroom-hit rate).
#[derive(Clone, Copy, Debug, Default)]
pub struct PatchReport {
    /// Cost-plane rewrites (`patch_costs`).
    pub cost_patches: usize,
    /// Edge edits absorbed by padding headroom.
    pub in_place: usize,
    /// Edge edits that repacked at least one bucket.
    pub repacked: usize,
}

impl PatchReport {
    pub fn note(&mut self, patch: EdgePatch) {
        match patch {
            EdgePatch::InPlace => self.in_place += 1,
            EdgePatch::Repacked => self.repacked += 1,
        }
    }
}

/// Fill one bucket's slabs from the matrix — pass 2 of [`SlabLayout::build`],
/// shared with the patch path so a repacked bucket is bit-identical to the
/// same bucket in a from-scratch build. `sources` must be ascending, with a
/// split (> width · 1) source's copies contiguous.
fn fill_bucket(
    kind: ProjectionKind,
    width: usize,
    sources: Vec<u32>,
    m: &BlockedMatrix,
    cost: &[f32],
) -> Bucket {
    let rows = sources.len();
    let n = rows * width;
    let mut bk = Bucket {
        kind,
        width,
        sources: Vec::with_capacity(rows),
        dest_idx: vec![0u32; n],
        edge_id: vec![u32::MAX; n],
        cost: vec![0.0f32; n],
        a: vec![vec![0.0f32; n]; m.num_families],
        mask: vec![0.0f32; n],
        real_edge_count: 0,
    };
    let mut row = 0usize;
    let mut cursor: Option<(u32, usize)> = None; // (source, next edge offset) for splits
    for &src in &sources {
        let i = src as usize;
        let (e0, e1) = (m.src_ptr[i], m.src_ptr[i + 1]);
        let start = match cursor {
            Some((s, off)) if s == src => e0 + off,
            _ => e0,
        };
        let take = (e1 - start).min(width);
        let base = row * width;
        for (col, e) in (start..start + take).enumerate() {
            bk.dest_idx[base + col] = m.dest_idx[e];
            bk.edge_id[base + col] = e as u32;
            bk.cost[base + col] = cost[e];
            for k in 0..m.num_families {
                bk.a[k][base + col] = m.a[k][e];
            }
            bk.mask[base + col] = 1.0;
        }
        bk.sources.push(src);
        bk.real_edge_count += take;
        cursor = if start + take < e1 {
            Some((src, start + take - e0))
        } else {
            None
        };
        row += 1;
    }
    bk
}

impl SlabLayout {
    /// Build the layout for sources `[src_lo, src_hi)` of `m` with costs
    /// `cost` (per edge, global indexing) and per-source projection kinds
    /// given by `kind_of` (the ProjectionMap of paper Table 1).
    ///
    /// Sources whose degree exceeds MAX_WIDTH are rejected for
    /// non-separable polytopes (simplex) — the row-wise projection needs
    /// the whole block in one row — and split across rows for separable
    /// ones (box).
    pub fn build(
        m: &BlockedMatrix,
        cost: &[f32],
        src_lo: usize,
        src_hi: usize,
        kind_of: &dyn Fn(usize) -> ProjectionKind,
    ) -> Result<SlabLayout, String> {
        assert!(src_lo <= src_hi && src_hi <= m.num_sources);
        assert_eq!(cost.len(), m.nnz());

        // Pass 1: count rows per (kind, width) bucket.
        use std::collections::BTreeMap;
        let mut groups: BTreeMap<(ProjectionKind, usize), Vec<u32>> = BTreeMap::new();
        for i in src_lo..src_hi {
            let deg = m.degree(i);
            if deg == 0 {
                continue; // isolated source: no variables
            }
            let kind = kind_of(i);
            if deg > MAX_WIDTH {
                if !kind.separable() {
                    return Err(format!(
                        "source {i} degree {deg} exceeds MAX_WIDTH {MAX_WIDTH} \
                         for non-separable {} projection",
                        kind.name()
                    ));
                }
                // separable: split into MAX_WIDTH chunks (handled in pass 2
                // by pushing the same source several times)
                let chunks = deg.div_ceil(MAX_WIDTH);
                groups
                    .entry((kind, MAX_WIDTH))
                    .or_default()
                    .extend(std::iter::repeat(i as u32).take(chunks));
            } else {
                groups.entry((kind, bucket_width(deg))).or_default().push(i as u32);
            }
        }

        // Pass 2: fill slabs.
        let mut buckets = Vec::with_capacity(groups.len());
        for ((kind, width), sources) in groups {
            buckets.push(fill_bucket(kind, width, sources, m, cost));
        }
        Ok(SlabLayout {
            buckets,
            num_families: m.num_families,
            num_dests: m.num_dests,
        })
    }

    pub fn total_rows(&self) -> usize {
        self.buckets.iter().map(|b| b.rows()).sum()
    }

    pub fn total_real_edges(&self) -> usize {
        self.buckets.iter().map(|b| b.real_edges()).sum()
    }

    pub fn total_padded_edges(&self) -> usize {
        self.buckets.iter().map(|b| b.padded_edges()).sum()
    }

    /// Padding overhead factor (paper: < 2 within each bucket).
    pub fn padding_factor(&self) -> f64 {
        self.total_padded_edges() as f64 / self.total_real_edges().max(1) as f64
    }

    /// Number of kernel launches per iteration under this layout
    /// (paper: 1 + ⌊log₂ s_max⌋ per kind).
    pub fn num_launches(&self) -> usize {
        self.buckets.len()
    }

    /// The canonical fixed chunk grid over this layout: each bucket's rows
    /// cut into ranges of a target size derived from the layout alone
    /// (`total_rows / MAX_CHUNKS`, floored at `MIN_CHUNK_ROWS`). Every
    /// consumer of the layout — the slab objective's thread pool, the
    /// sharded backend, the distributed worker pool — must use THIS grid:
    /// per-chunk partial reductions merged in ascending grid index are the
    /// definition of the layout's bit-exact evaluation order.
    pub fn fixed_chunk_grid(&self) -> Vec<SlabChunk> {
        let target = self.total_rows().div_ceil(MAX_CHUNKS).max(MIN_CHUNK_ROWS);
        let mut grid = Vec::new();
        for (b, bk) in self.buckets.iter().enumerate() {
            let rows = bk.rows();
            let mut lo = 0usize;
            while lo < rows {
                let hi = (lo + target).min(rows);
                grid.push(SlabChunk { bucket: b, row_lo: lo, row_hi: hi });
                lo = hi;
            }
        }
        grid
    }

    /// Real (non-padding) edges inside one chunk — a mask scan, intended
    /// for build/partition time, not the per-iteration path.
    pub fn chunk_real_edges(&self, c: &SlabChunk) -> usize {
        let bk = &self.buckets[c.bucket];
        let w = bk.width;
        bk.mask[c.row_lo * w..c.row_hi * w].iter().filter(|&&m| m > 0.0).count()
    }

    /// Cumulative real-edge pointer over a chunk grid — the `src_ptr`
    /// analogue that `distributed::balanced_partition` consumes to cut
    /// the grid into contiguous shard ranges balanced by **real** edge
    /// count (padding is free to evaluate relative to real work and must
    /// not skew the split).
    pub fn chunk_edge_ptr(&self, grid: &[SlabChunk]) -> Vec<usize> {
        let mut ptr = Vec::with_capacity(grid.len() + 1);
        ptr.push(0usize);
        for c in grid {
            ptr.push(ptr.last().unwrap() + self.chunk_real_edges(c));
        }
        ptr
    }

    /// Rewrite the cost plane in place from a perturbed per-edge cost
    /// vector (global edge indexing) — the c-delta path. Structure (edge
    /// pattern, a-planes, masks, grid) is untouched, so this never
    /// invalidates anything derived from the layout.
    pub fn patch_costs(&mut self, cost: &[f32]) {
        for bk in &mut self.buckets {
            for (c, &eid) in bk.cost.iter_mut().zip(&bk.edge_id) {
                if eid != u32::MAX {
                    *c = cost[eid as usize];
                }
            }
        }
    }

    /// Shift stored global edge ids after a CSR splice: ids `>= from` move
    /// by `delta` (+1 after an insert at `from`, −1 after a delete, where
    /// the deleted id itself lives in the edited source's row and is
    /// rewritten by the caller).
    fn renumber_edges(&mut self, from: u32, delta: i32) {
        for bk in &mut self.buckets {
            for eid in &mut bk.edge_id {
                if *eid != u32::MAX && *eid >= from {
                    *eid = eid.wrapping_add(delta as u32);
                }
            }
        }
    }

    /// Rewrite one bucket row from the (post-edit) matrix: the in-place
    /// fast path of `patch_edge`, valid only when the source occupies a
    /// single row and its new degree still fits the bucket width.
    fn refill_row(&mut self, bucket: usize, row: usize, m: &BlockedMatrix, cost: &[f32]) {
        let bk = &mut self.buckets[bucket];
        let w = bk.width;
        let base = row * w;
        let i = bk.sources[row] as usize;
        let (e0, e1) = (m.src_ptr[i], m.src_ptr[i + 1]);
        let deg = e1 - e0;
        debug_assert!(deg <= w);
        let old_real =
            bk.mask[base..base + w].iter().filter(|&&v| v > 0.0).count();
        for col in 0..w {
            if col < deg {
                let e = e0 + col;
                bk.dest_idx[base + col] = m.dest_idx[e];
                bk.edge_id[base + col] = e as u32;
                bk.cost[base + col] = cost[e];
                for k in 0..m.num_families {
                    bk.a[k][base + col] = m.a[k][e];
                }
                bk.mask[base + col] = 1.0;
            } else {
                bk.dest_idx[base + col] = 0;
                bk.edge_id[base + col] = u32::MAX;
                bk.cost[base + col] = 0.0;
                for k in 0..m.num_families {
                    bk.a[k][base + col] = 0.0;
                }
                bk.mask[base + col] = 0.0;
            }
        }
        bk.real_edge_count = bk.real_edge_count + deg - old_real;
    }

    /// Apply one edge insert or delete to the resident layout.
    ///
    /// `m`/`cost` are the POST-edit matrix and cost planes; `edge` is the
    /// spliced global position (the new edge's index after an insert, the
    /// removed edge's old index after a delete); `source` is the edited
    /// source block and `kind` its projection kind. The patched layout is
    /// bit-identical — plane by plane, bucket by bucket — to
    /// `SlabLayout::build` of the post-edit matrix (the parity gate the
    /// serve tests assert), without ever re-laying-out untouched sources:
    ///
    /// 1. a renumber sweep shifts stored edge ids past the splice point,
    /// 2. if the source keeps its (kind, width) bucket and occupies one
    ///    row, that row alone is rewritten using the padding headroom
    ///    ([`EdgePatch::InPlace`]),
    /// 3. otherwise the source's old and new buckets are repacked
    ///    (created/removed as needed, in the build's (kind, width) order)
    ///    and the caller must refresh its chunk grid
    ///    ([`EdgePatch::Repacked`]).
    pub fn patch_edge(
        &mut self,
        m: &BlockedMatrix,
        cost: &[f32],
        source: usize,
        edge: usize,
        insert: bool,
        kind: ProjectionKind,
    ) -> Result<EdgePatch, String> {
        assert_eq!(cost.len(), m.nnz());
        assert_eq!(m.num_families, self.num_families);
        let new_deg = m.degree(source);
        // Reject before touching anything: an error must leave the
        // resident layout exactly as it was.
        if new_deg > MAX_WIDTH && !kind.separable() {
            return Err(format!(
                "source {source} degree {new_deg} exceeds MAX_WIDTH {MAX_WIDTH} \
                 for non-separable {} projection",
                kind.name()
            ));
        }
        if insert {
            self.renumber_edges(edge as u32, 1);
        } else {
            self.renumber_edges(edge as u32 + 1, -1);
        }

        // Locate the source's current rows (all in one bucket: kind is
        // fixed per source and width is a function of its degree).
        let old = self.buckets.iter().enumerate().find_map(|(bi, bk)| {
            let lo = bk.sources.partition_point(|&s| s < source as u32);
            let hi = bk.sources.partition_point(|&s| s <= source as u32);
            (lo < hi).then_some((bi, hi - lo))
        });

        // In-place fast path: same bucket, one row, degree still fits.
        if let Some((bi, rows)) = old {
            if rows == 1
                && new_deg > 0
                && new_deg <= MAX_WIDTH
                && self.buckets[bi].kind == kind
                && self.buckets[bi].width == bucket_width(new_deg)
            {
                let row = self.buckets[bi]
                    .sources
                    .partition_point(|&s| s < source as u32);
                self.refill_row(bi, row, m, cost);
                return Ok(EdgePatch::InPlace);
            }
        }

        // Repack: pull the source out of its old bucket, re-insert it at
        // its new (kind, width) position. Buckets stay in build order
        // ((kind, width) ascending), so plane parity with a fresh build
        // is preserved.
        if let Some((bi, _)) = old {
            let (k, w) = (self.buckets[bi].kind, self.buckets[bi].width);
            let sources: Vec<u32> = self.buckets[bi]
                .sources
                .iter()
                .copied()
                .filter(|&s| s != source as u32)
                .collect();
            if sources.is_empty() {
                self.buckets.remove(bi);
            } else {
                self.buckets[bi] = fill_bucket(k, w, sources, m, cost);
            }
        }
        if new_deg > 0 {
            // overwide + non-separable was rejected up front
            let (width, copies) = if new_deg > MAX_WIDTH {
                (MAX_WIDTH, new_deg.div_ceil(MAX_WIDTH))
            } else {
                (bucket_width(new_deg), 1)
            };
            match self
                .buckets
                .binary_search_by(|b| (b.kind, b.width).cmp(&(kind, width)))
            {
                Ok(bi) => {
                    let mut sources = std::mem::take(&mut self.buckets[bi].sources);
                    let at = sources.partition_point(|&s| s < source as u32);
                    for _ in 0..copies {
                        sources.insert(at, source as u32);
                    }
                    self.buckets[bi] = fill_bucket(kind, width, sources, m, cost);
                }
                Err(bi) => {
                    let sources = vec![source as u32; copies];
                    self.buckets.insert(bi, fill_bucket(kind, width, sources, m, cost));
                }
            }
        }
        Ok(EdgePatch::Repacked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(degrees: &[usize], num_dests: usize) -> (BlockedMatrix, Vec<f32>) {
        let mut src_ptr = vec![0usize];
        let mut dest_idx = Vec::new();
        for &d in degrees {
            for j in 0..d {
                dest_idx.push((j % num_dests) as u32);
            }
            src_ptr.push(dest_idx.len());
        }
        let nnz = dest_idx.len();
        let a = vec![(0..nnz).map(|e| 1.0 + e as f32 * 0.1).collect()];
        let cost = (0..nnz).map(|e| -(e as f32) * 0.01 - 0.1).collect();
        (
            BlockedMatrix {
                num_sources: degrees.len(),
                num_dests,
                num_families: 1,
                src_ptr,
                dest_idx,
                a,
            },
            cost,
        )
    }

    #[test]
    fn bucket_width_pow2() {
        assert_eq!(bucket_width(1), MIN_WIDTH);
        assert_eq!(bucket_width(4), 4);
        assert_eq!(bucket_width(5), 8);
        assert_eq!(bucket_width(8), 8);
        assert_eq!(bucket_width(9), 16);
        assert_eq!(bucket_width(4000), MAX_WIDTH);
    }

    #[test]
    fn builds_buckets_by_log2_degree() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let l = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        let widths: Vec<usize> = l.buckets.iter().map(|b| b.width).collect();
        assert_eq!(widths, vec![4, 8, 16, 32]);
        // w=4 bucket has sources 0 (deg3), 1 (deg4), 5 (deg2)
        assert_eq!(l.buckets[0].sources, vec![0, 1, 5]);
        assert_eq!(l.total_rows(), 6);
        assert_eq!(l.total_real_edges(), 3 + 4 + 5 + 9 + 17 + 2);
    }

    #[test]
    fn padding_factor_below_two() {
        let degrees: Vec<usize> = (1..200).collect();
        let (m, cost) = matrix(&degrees, 256);
        let l = SlabLayout::build(&m, &cost, 0, degrees.len(), &|_| ProjectionKind::Box).unwrap();
        assert!(l.padding_factor() < 2.3, "factor={}", l.padding_factor());
        // and launches bounded by kinds × widths
        assert!(l.num_launches() <= 1 + (256f64).log2() as usize);
    }

    #[test]
    fn slab_contents_match_matrix() {
        let (m, cost) = matrix(&[3, 4], 8);
        let l = SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Simplex).unwrap();
        let b = &l.buckets[0];
        assert_eq!(b.width, 4);
        assert_eq!(b.rows(), 2);
        // row 0 = source 0 (deg 3): 3 real + 1 pad
        assert_eq!(&b.mask[0..4], &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.dest_idx[0..3], m.dest_idx[0..3]);
        assert_eq!(b.cost[0..3], cost[0..3]);
        assert_eq!(b.a[0][0..3], m.a[0][0..3]);
        // padding carries zeros
        assert_eq!(b.cost[3], 0.0);
        assert_eq!(b.a[0][3], 0.0);
    }

    #[test]
    fn shard_ranges_partition_edges() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let full = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Box).unwrap();
        let a = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Box).unwrap();
        let b = SlabLayout::build(&m, &cost, 3, 6, &|_| ProjectionKind::Box).unwrap();
        assert_eq!(
            full.total_real_edges(),
            a.total_real_edges() + b.total_real_edges()
        );
    }

    #[test]
    fn simplex_rejects_overwide_source() {
        let (m, cost) = matrix(&[MAX_WIDTH + 1], MAX_WIDTH + 2);
        let err = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Simplex);
        assert!(err.is_err());
    }

    #[test]
    fn box_splits_overwide_source() {
        let deg = MAX_WIDTH + 10;
        let (m, cost) = matrix(&[deg], MAX_WIDTH + 16);
        let l = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Box).unwrap();
        assert_eq!(l.total_real_edges(), deg);
        assert_eq!(l.total_rows(), 2); // split into two rows
        assert_eq!(l.buckets[0].sources, vec![0, 0]);
    }

    #[test]
    fn mixed_projection_kinds_bucket_separately() {
        let (m, cost) = matrix(&[3, 3, 3, 3], 8);
        let l = SlabLayout::build(&m, &cost, 0, 4, &|i| {
            if i % 2 == 0 { ProjectionKind::Simplex } else { ProjectionKind::Box }
        })
        .unwrap();
        assert_eq!(l.num_launches(), 2);
        let kinds: Vec<_> = l.buckets.iter().map(|b| b.kind).collect();
        assert!(kinds.contains(&ProjectionKind::Simplex));
        assert!(kinds.contains(&ProjectionKind::Box));
    }

    #[test]
    fn stored_real_edge_count_matches_mask_scan() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2, MAX_WIDTH + 10], MAX_WIDTH + 16);
        let l = SlabLayout::build(&m, &cost, 0, 7, &|_| ProjectionKind::Box).unwrap();
        for bk in &l.buckets {
            let scanned = bk.mask.iter().filter(|&&v| v > 0.0).count();
            assert_eq!(bk.real_edges(), scanned);
        }
        assert_eq!(l.total_real_edges(), 3 + 4 + 5 + 9 + 17 + 2 + MAX_WIDTH + 10);
    }

    #[test]
    fn fixed_chunk_grid_covers_rows_in_order() {
        let degrees: Vec<usize> = (1..400).map(|i| 1 + i % 13).collect();
        let (m, cost) = matrix(&degrees, 64);
        let l = SlabLayout::build(&m, &cost, 0, degrees.len(), &|_| ProjectionKind::Box).unwrap();
        let grid = l.fixed_chunk_grid();
        // chunks cover every bucket's rows exactly once, in ascending
        // (bucket, row) order
        let mut covered = 0usize;
        let mut prev: Option<SlabChunk> = None;
        for c in &grid {
            assert!(c.row_lo < c.row_hi);
            if let Some(p) = prev {
                if p.bucket == c.bucket {
                    assert_eq!(p.row_hi, c.row_lo, "gap within bucket");
                } else {
                    assert!(c.bucket > p.bucket, "buckets out of order");
                    assert_eq!(p.row_hi, l.buckets[p.bucket].rows(), "bucket not exhausted");
                    assert_eq!(c.row_lo, 0);
                }
            } else {
                assert_eq!((c.bucket, c.row_lo), (0, 0));
            }
            covered += c.rows();
            prev = Some(*c);
        }
        assert_eq!(covered, l.total_rows());
        // real-edge bookkeeping is consistent with the buckets
        assert_eq!(
            grid.iter().map(|c| l.chunk_real_edges(c)).sum::<usize>(),
            l.total_real_edges()
        );
        let ptr = l.chunk_edge_ptr(&grid);
        assert_eq!(ptr.len(), grid.len() + 1);
        assert_eq!(*ptr.last().unwrap(), l.total_real_edges());
    }

    #[test]
    fn zero_degree_sources_skipped() {
        let (m, cost) = matrix(&[0, 3, 0], 8);
        let l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_eq!(l.total_rows(), 1);
        assert_eq!(l.buckets[0].sources, vec![1]);
    }

    /// Splice one edge into the CSR at the end of `source`'s range,
    /// returning its global position — the test mirror of the serve host's
    /// delta application.
    fn insert_edge(
        m: &mut BlockedMatrix,
        cost: &mut Vec<f32>,
        source: usize,
        dest: u32,
        aval: f32,
        cval: f32,
    ) -> usize {
        let p = m.src_ptr[source + 1];
        m.dest_idx.insert(p, dest);
        for plane in &mut m.a {
            plane.insert(p, aval);
        }
        cost.insert(p, cval);
        for ptr in &mut m.src_ptr[source + 1..] {
            *ptr += 1;
        }
        p
    }

    /// Remove `source`'s `col`-th edge from the CSR, returning its old
    /// global position.
    fn remove_edge(
        m: &mut BlockedMatrix,
        cost: &mut Vec<f32>,
        source: usize,
        col: usize,
    ) -> usize {
        let p = m.src_ptr[source] + col;
        m.dest_idx.remove(p);
        for plane in &mut m.a {
            plane.remove(p);
        }
        cost.remove(p);
        for ptr in &mut m.src_ptr[source + 1..] {
            *ptr -= 1;
        }
        p
    }

    /// Plane-by-plane bit equality — the delta-path parity gate.
    fn assert_layout_bit_eq(a: &SlabLayout, b: &SlabLayout) {
        assert_eq!(a.num_families, b.num_families);
        assert_eq!(a.num_dests, b.num_dests);
        assert_eq!(a.buckets.len(), b.buckets.len(), "bucket count");
        for (i, (x, y)) in a.buckets.iter().zip(&b.buckets).enumerate() {
            assert_eq!(x.kind, y.kind, "bucket {i} kind");
            assert_eq!(x.width, y.width, "bucket {i} width");
            assert_eq!(x.sources, y.sources, "bucket {i} sources");
            assert_eq!(x.dest_idx, y.dest_idx, "bucket {i} dest_idx");
            assert_eq!(x.edge_id, y.edge_id, "bucket {i} edge_id");
            assert_eq!(x.real_edge_count, y.real_edge_count, "bucket {i} real edges");
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&x.cost), bits(&y.cost), "bucket {i} cost");
            assert_eq!(bits(&x.mask), bits(&y.mask), "bucket {i} mask");
            for k in 0..x.a.len() {
                assert_eq!(bits(&x.a[k]), bits(&y.a[k]), "bucket {i} family {k}");
            }
        }
    }

    #[test]
    fn patch_costs_matches_rebuild() {
        let (m, mut cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        for (e, c) in cost.iter_mut().enumerate() {
            *c += 0.001 * e as f32;
        }
        l.patch_costs(&cost);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn insert_within_headroom_is_in_place() {
        // source 0 has degree 3 in a width-4 bucket: one edge of headroom
        let (mut m, mut cost) = matrix(&[3, 4, 5], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        let grid_before = l.fixed_chunk_grid();
        let p = insert_edge(&mut m, &mut cost, 0, 30, 2.5, -0.9);
        let patch = l.patch_edge(&m, &cost, 0, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::InPlace);
        assert_eq!(l.fixed_chunk_grid(), grid_before, "in-place keeps the grid");
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn insert_overflowing_bucket_repacks() {
        // source 1 has degree 4 = full width-4 row: the insert overflows
        // into the width-8 bucket (which already holds source 2)
        let (mut m, mut cost) = matrix(&[3, 4, 5], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        let p = insert_edge(&mut m, &mut cost, 1, 31, 1.25, -0.45);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn delete_in_place_and_across_widths() {
        let (mut m, mut cost) = matrix(&[4, 5, 9], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        // 4 → 3 stays in the width-4 bucket
        let p = remove_edge(&mut m, &mut cost, 0, 1);
        let patch = l.patch_edge(&m, &cost, 0, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::InPlace);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        // 5 → 4 crosses width 8 → 4
        let p = remove_edge(&mut m, &mut cost, 1, 0);
        let patch = l.patch_edge(&m, &cost, 1, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
    }

    #[test]
    fn edge_patch_creates_and_removes_sources_and_buckets() {
        // source 1 starts isolated (degree 0); source 2's width-16 bucket
        // exists only because of source 2
        let (mut m, mut cost) = matrix(&[3, 0, 9], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_eq!(l.num_launches(), 2);
        // 0 → 1: the isolated source enters the width-4 bucket
        let p = insert_edge(&mut m, &mut cost, 1, 7, 0.5, -0.2);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        assert_eq!(l.buckets[0].sources, vec![0, 1]);
        // 1 → 0: and leaves it again
        let p = remove_edge(&mut m, &mut cost, 1, 0);
        let patch = l.patch_edge(&m, &cost, 1, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        // 9 → 8 (width 16 → 8): the width-16 bucket disappears entirely
        let p = remove_edge(&mut m, &mut cost, 2, 4);
        let patch = l.patch_edge(&m, &cost, 2, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
        assert!(l.buckets.iter().all(|b| b.width != 16));
    }

    #[test]
    fn split_source_edits_repack_with_parity() {
        let deg = MAX_WIDTH + 10;
        let (mut m, mut cost) = matrix(&[3, deg], MAX_WIDTH + 16);
        let mut l = SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Box).unwrap();
        let p = insert_edge(&mut m, &mut cost, 1, (MAX_WIDTH + 12) as u32, 1.0, -0.3);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Box).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Box).unwrap(),
        );
        assert_eq!(l.total_real_edges(), 3 + deg + 1);
    }

    #[test]
    fn patch_rejects_overwide_non_separable() {
        let (mut m, mut cost) = matrix(&[MAX_WIDTH], MAX_WIDTH + 4);
        let mut l = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Simplex).unwrap();
        let p = insert_edge(&mut m, &mut cost, 0, (MAX_WIDTH + 1) as u32, 1.0, -0.1);
        assert!(l.patch_edge(&m, &cost, 0, p, true, ProjectionKind::Simplex).is_err());
    }

    #[test]
    fn patch_report_tallies() {
        let mut r = PatchReport::default();
        r.note(EdgePatch::InPlace);
        r.note(EdgePatch::InPlace);
        r.note(EdgePatch::Repacked);
        r.cost_patches += 1;
        assert_eq!((r.in_place, r.repacked, r.cost_patches), (2, 1, 1));
    }
}
