//! Log₂-bucketed padded slab layout (paper §6 "Batched projection
//! operator").
//!
//! Sources are grouped by slice length (degree) into geometric buckets;
//! each bucket's slices are gathered into a dense slab padded to the
//! bucket's upper bound. One batched kernel launch per bucket replaces one
//! launch per source, while geometric bucketing bounds padding waste —
//! below 2× under the default pow2 [`WidthPolicy`], below 1.5× under the
//! quarter-step table.
//!
//! The slab row order remembers its source ids so the coordinator can
//! gather λ into per-edge `u` and scatter-add `a ⊙ x` back into the dual
//! gradient.
//!
//! **Build pipeline** ([`SlabLayout::build_opts`], DESIGN.md §11): a
//! deterministically parallel counting sort. Pass 1 classifies each source
//! once, counts rows per (kind, width-slot) cell in a dense counter array,
//! prefix-sums the nonzero cells into bucket row offsets, and scatters
//! sources into their rows — the inverted source→row map that
//! [`SlabIndex`] retains for the serve path. Pass 2 fills the SoA planes
//! chunk-by-chunk over the fixed grid with `std::thread::scope`; every
//! task owns a disjoint row range, so the planes are bit-identical to a
//! serial fill at any thread count. The same row primitive backs the
//! repack path: [`SlabLayout::patch_edge`] splices and refills only the
//! edited source's rows.
//!
//! On top of the buckets sits the **fixed chunk grid**
//! ([`SlabLayout::fixed_chunk_grid`]): every bucket's rows cut into
//! [`SlabChunk`] row ranges by a rule that depends on the layout alone —
//! never on thread or shard counts. The grid is the shared unit of both
//! intra-process parallelism (`backend::slab_cpu`) and cross-shard
//! partitioning (`backend::sharded`, `distributed::worker`): shards own
//! contiguous chunk ranges, so merging per-chunk partial reductions in
//! ascending chunk index reproduces the exact f32 summation order of a
//! single-shard evaluation, making sharded solves bit-identical to
//! unsharded ones.

use super::blocked::BlockedMatrix;
use crate::projection::ProjectionKind;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Minimum slab width (tiny rows are padded up to this).
pub const MIN_WIDTH: usize = 4;
/// Maximum slab width supported by the AOT artifact family.
pub const MAX_WIDTH: usize = 512;

/// Target size of the fixed chunk grid. Fixed (never derived from thread
/// or shard counts) so the chunk-ordered reduction — and therefore every
/// bit of the result — is identical at any pool width and shard count.
/// Chunks never span buckets, so the actual grid can exceed this by up to
/// one chunk per bucket.
pub const MAX_CHUNKS: usize = 32;
/// Minimum rows per chunk — below this the per-chunk bookkeeping
/// dominates the math.
pub const MIN_CHUNK_ROWS: usize = 64;

const POW2_WIDTHS: [usize; 8] = [4, 8, 16, 32, 64, 128, 256, 512];
const QUARTER_WIDTHS: [usize; 14] =
    [4, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512];

/// Degree→width rounding table for the slab buckets.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WidthPolicy {
    /// Powers of two in [MIN_WIDTH, MAX_WIDTH] — the paper's §6 scheme and
    /// the bit-compatible default (identical buckets to [`bucket_width`]).
    #[default]
    Pow2,
    /// Quarter steps: powers of two plus their midpoints
    /// (4, 8, 12, 16, 24, 32, …) — bounds per-row padding waste below 1.5×
    /// instead of 2×, at the price of up to 2× more launches.
    QuarterStep,
}

impl WidthPolicy {
    /// The ascending width table: every bucket width under this policy is
    /// an entry of this table, and a degree's width slot is its position.
    pub fn widths(self) -> &'static [usize] {
        match self {
            WidthPolicy::Pow2 => &POW2_WIDTHS,
            WidthPolicy::QuarterStep => &QUARTER_WIDTHS,
        }
    }

    /// Width-table slot of `degree`; degrees past MAX_WIDTH clamp to the
    /// last slot (the split path for separable kinds).
    fn slot_for(self, degree: usize) -> usize {
        let ws = self.widths();
        ws.partition_point(|&w| w < degree).min(ws.len() - 1)
    }

    /// Round `degree` up to its bucket width under this policy.
    pub fn width_for(self, degree: usize) -> usize {
        self.widths()[self.slot_for(degree)]
    }

    pub fn name(self) -> &'static str {
        match self {
            WidthPolicy::Pow2 => "pow2",
            WidthPolicy::QuarterStep => "quarter",
        }
    }

    pub fn parse(spec: &str) -> Option<WidthPolicy> {
        match spec {
            "pow2" => Some(WidthPolicy::Pow2),
            "quarter" | "quarter-step" => Some(WidthPolicy::QuarterStep),
            _ => None,
        }
    }
}

/// Round degree up to the default bucket width: next power of two,
/// clamped to [MIN_WIDTH, MAX_WIDTH] (shorthand for
/// `WidthPolicy::Pow2.width_for`).
pub fn bucket_width(degree: usize) -> usize {
    WidthPolicy::Pow2.width_for(degree)
}

/// Knobs for [`SlabLayout::build_opts`]. `Default` (pow2 widths, serial
/// fill) reproduces [`SlabLayout::build`] bit-for-bit.
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildOptions {
    /// Degree→width rounding table.
    pub policy: WidthPolicy,
    /// Plane-fill threads for pass 2; 0 or 1 fills serially. Any value
    /// yields bit-identical planes — threads race only to *claim* disjoint
    /// chunks, never to write.
    pub threads: usize,
}

/// One unit of the fixed parallel/shard grid: a row range within one
/// bucket. Chunks never span buckets, so each chunk projects with one
/// operator at one width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlabChunk {
    /// Index into [`SlabLayout::buckets`].
    pub bucket: usize,
    /// First row (inclusive) of the range within the bucket.
    pub row_lo: usize,
    /// Last row (exclusive) of the range within the bucket.
    pub row_hi: usize,
}

impl SlabChunk {
    pub fn rows(&self) -> usize {
        self.row_hi - self.row_lo
    }
}

/// One bucket: a dense `[rows × width]` slab of edges.
#[derive(Clone, Debug)]
pub struct Bucket {
    /// Projection kind for every row in this bucket.
    pub kind: ProjectionKind,
    /// Padded width (a [`WidthPolicy`] table entry in
    /// [MIN_WIDTH, MAX_WIDTH]).
    pub width: usize,
    /// Source id of each row.
    pub sources: Vec<u32>,
    /// Real (non-padding) entries per row (`row_len[r] <= width`), fixed
    /// at build time so partition-time consumers prefix-sum real edges in
    /// O(rows) instead of rescanning masks.
    pub row_len: Vec<u16>,
    /// Flattened [rows × width] destination index (0 on padding).
    pub dest_idx: Vec<u32>,
    /// Flattened [rows × width] global edge index (u32::MAX on padding) —
    /// lets the coordinator apply global constraint rows and recover the
    /// per-edge primal without re-deriving chunk offsets.
    pub edge_id: Vec<u32>,
    /// Flattened [rows × width] cost coefficients (0 on padding).
    pub cost: Vec<f32>,
    /// Per-family flattened [rows × width] constraint coefficients.
    pub a: Vec<Vec<f32>>,
    /// Flattened [rows × width] validity mask (1 real, 0 padding).
    pub mask: Vec<f32>,
    /// Number of real (non-padding) edges, counted once at build time so
    /// per-iteration consumers don't rescan the mask.
    pub real_edge_count: usize,
}

impl Bucket {
    pub fn rows(&self) -> usize {
        self.sources.len()
    }

    pub fn real_edges(&self) -> usize {
        self.real_edge_count
    }

    pub fn padded_edges(&self) -> usize {
        self.dest_idx.len()
    }
}

/// The full bucketed layout of one (shard of a) matching LP.
#[derive(Clone, Debug)]
pub struct SlabLayout {
    pub buckets: Vec<Bucket>,
    pub num_families: usize,
    pub num_dests: usize,
    /// Width table the buckets were built with (patches must round new
    /// degrees with the same table to preserve rebuild parity).
    pub policy: WidthPolicy,
}

/// Per-bucket padding achieved under the active [`WidthPolicy`] — the
/// observability half of the width-bucketing knob
/// ([`SlabLayout::padding_report`]).
#[derive(Clone, Debug)]
pub struct BucketPadding {
    pub kind: String,
    pub width: usize,
    pub rows: usize,
    pub real_edges: usize,
    pub padded_edges: usize,
    /// padded / real for this bucket (>= 1).
    pub factor: f64,
}

/// How an edge insert/delete was absorbed by [`SlabLayout::patch_edge`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePatch {
    /// The edited source stayed in its bucket row — padding headroom
    /// absorbed the edit and only that row was rewritten. Row counts are
    /// unchanged, so an existing chunk grid remains valid.
    InPlace,
    /// The edit moved the source across buckets (width transition, bucket
    /// creation/removal, or a split source): the affected buckets were
    /// repacked. Row counts may have changed — recompute the chunk grid.
    Repacked,
}

/// Tally of delta operations applied to a resident layout (serve-path
/// diagnostics: the in-place / repack ratio is the headroom-hit rate).
#[derive(Clone, Copy, Debug, Default)]
pub struct PatchReport {
    /// Cost-plane rewrites (`patch_costs`).
    pub cost_patches: usize,
    /// Edge edits absorbed by padding headroom.
    pub in_place: usize,
    /// Edge edits that repacked at least one bucket.
    pub repacked: usize,
}

impl PatchReport {
    pub fn note(&mut self, patch: EdgePatch) {
        match patch {
            EdgePatch::InPlace => self.in_place += 1,
            EdgePatch::Repacked => self.repacked += 1,
        }
    }
}

/// What a patch did to bucket structure — drives the incremental index
/// maintenance in [`SlabLayout::patch_edge_indexed`].
enum PatchTouch {
    /// Row contents changed but no rows moved.
    None,
    /// These buckets' row assignments changed; bucket indices are stable.
    Buckets(Vec<usize>),
    /// Buckets were created or removed — bucket indices shifted.
    Reshaped,
}

/// Position of `kind` in the sorted distinct-kind table. The kind is
/// always present (the table was collected from the same tags), so the
/// not-found arm is unreachable; `unwrap_or_else` keeps it panic-free.
fn kind_index(kinds: &[ProjectionKind], kind: ProjectionKind) -> usize {
    kinds.binary_search(&kind).unwrap_or_else(|at| at)
}

/// Rows per chunk of the canonical grid for a layout with `total_rows`.
fn chunk_target(total_rows: usize) -> usize {
    total_rows.div_ceil(MAX_CHUNKS).max(MIN_CHUNK_ROWS)
}

/// Cut `rows` into `(lo, hi)` ranges of at most `target` rows — the
/// per-bucket piece of the fixed chunk grid, shared between
/// [`SlabLayout::fixed_chunk_grid`] and the parallel fill so fill tasks
/// coincide exactly with grid chunks.
fn bucket_chunks(rows: usize, target: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + target).min(rows);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Split `n` elements off the front of `*rest`, shrinking it — the borrow
/// split that hands pass 2 its disjoint `&mut` plane windows without
/// unsafe.
fn carve<'a, T>(rest: &mut &'a mut [T], n: usize) -> &'a mut [T] {
    let (head, tail) = std::mem::take(rest).split_at_mut(n);
    *rest = tail;
    head
}

/// How many immediately-preceding rows of `sources` hold the same source
/// as row `at` — the split-copy offset a fill task starts from (copies of
/// an over-wide separable source are contiguous).
fn split_run(sources: &[u32], at: usize) -> usize {
    if at == 0 || at >= sources.len() {
        return 0;
    }
    let src = sources[at];
    let mut run = 0usize;
    while at - run > 0 && sources[at - run - 1] == src {
        run += 1;
    }
    run
}

/// One chunk-sized unit of pass-2 fill work: disjoint `&mut` windows over
/// one bucket's planes, covering rows `[row_lo, row_lo + sources.len())`
/// of that bucket.
struct FillTask<'a> {
    width: usize,
    /// Split-copy offset of the first row (how many earlier rows of the
    /// same source precede this window).
    run0: usize,
    sources: &'a [u32],
    dest_idx: &'a mut [u32],
    edge_id: &'a mut [u32],
    cost: &'a mut [f32],
    a: Vec<&'a mut [f32]>,
    mask: &'a mut [f32],
}

/// Fill one task's rows from the matrix — the row primitive shared by the
/// from-scratch build, the range-targeted repack, and (transitively)
/// `patch_edge`, so a repacked bucket is bit-identical to the same bucket
/// in a fresh build. Planes must arrive in shell state (padding
/// defaults); only the real prefix of each row is written.
fn fill_task(t: &mut FillTask<'_>, m: &BlockedMatrix, cost: &[f32]) {
    let w = t.width;
    let mut run = t.run0;
    for (rr, &src) in t.sources.iter().enumerate() {
        if rr > 0 {
            run = if t.sources[rr - 1] == src { run + 1 } else { 0 };
        }
        let i = src as usize;
        let (e0, e1) = (m.src_ptr[i], m.src_ptr[i + 1]);
        let start = e0 + run * w;
        let take = (e1 - start).min(w);
        let base = rr * w;
        for (col, e) in (start..start + take).enumerate() {
            t.dest_idx[base + col] = m.dest_idx[e];
            t.edge_id[base + col] = e as u32;
            t.cost[base + col] = cost[e];
            for (k, plane) in t.a.iter_mut().enumerate() {
                plane[base + col] = m.a[k][e];
            }
            t.mask[base + col] = 1.0;
        }
    }
}

/// Allocate a bucket with every plane in padding state and `row_len` /
/// `real_edge_count` computed from the matrix — pass 1's output, filled
/// by pass 2. `sources` must be ascending with split copies contiguous.
fn bucket_shell(
    kind: ProjectionKind,
    width: usize,
    sources: Vec<u32>,
    m: &BlockedMatrix,
) -> Bucket {
    let rows = sources.len();
    let n = rows * width;
    let mut row_len = Vec::with_capacity(rows);
    let mut run = 0usize;
    for (r, &src) in sources.iter().enumerate() {
        if r > 0 {
            run = if sources[r - 1] == src { run + 1 } else { 0 };
        }
        let deg = m.degree(src as usize);
        row_len.push((deg - run * width).min(width) as u16);
    }
    let real = row_len.iter().map(|&l| l as usize).sum::<usize>();
    Bucket {
        kind,
        width,
        sources,
        row_len,
        dest_idx: vec![0u32; n],
        edge_id: vec![u32::MAX; n],
        cost: vec![0.0f32; n],
        a: vec![vec![0.0f32; n]; m.num_families],
        mask: vec![0.0f32; n],
        real_edge_count: real,
    }
}

/// Range-targeted refill: rewrite rows `[row_lo, row_hi)` of one bucket
/// from the matrix through the same [`fill_task`] primitive as the
/// from-scratch build. The range's planes must be in padding state.
fn fill_bucket_rows(
    bk: &mut Bucket,
    row_lo: usize,
    row_hi: usize,
    m: &BlockedMatrix,
    cost: &[f32],
) {
    let w = bk.width;
    let mut task = FillTask {
        width: w,
        run0: split_run(&bk.sources, row_lo),
        sources: &bk.sources[row_lo..row_hi],
        dest_idx: &mut bk.dest_idx[row_lo * w..row_hi * w],
        edge_id: &mut bk.edge_id[row_lo * w..row_hi * w],
        cost: &mut bk.cost[row_lo * w..row_hi * w],
        a: bk.a.iter_mut().map(|p| &mut p[row_lo * w..row_hi * w]).collect(),
        mask: &mut bk.mask[row_lo * w..row_hi * w],
    };
    fill_task(&mut task, m, cost);
}

impl SlabLayout {
    /// Build the layout for sources `[src_lo, src_hi)` of `m` with costs
    /// `cost` (per edge, global indexing) and per-source projection kinds
    /// given by `kind_of` (the ProjectionMap of paper Table 1), under the
    /// default [`BuildOptions`] (pow2 widths, serial fill).
    ///
    /// Sources whose degree exceeds MAX_WIDTH are rejected for
    /// non-separable polytopes (simplex) — the row-wise projection needs
    /// the whole block in one row — and split across rows for separable
    /// ones (box).
    pub fn build(
        m: &BlockedMatrix,
        cost: &[f32],
        src_lo: usize,
        src_hi: usize,
        kind_of: &dyn Fn(usize) -> ProjectionKind,
    ) -> Result<SlabLayout, String> {
        Self::build_opts(m, cost, src_lo, src_hi, kind_of, BuildOptions::default())
    }

    /// [`Self::build`] with explicit [`BuildOptions`]: the counting-sort
    /// pipeline (DESIGN.md §11).
    ///
    /// Pass 1 classifies each source once (`kind_of` is called exactly
    /// once per non-isolated source), counts rows per (kind, width-slot)
    /// cell in a dense counter array, prefix-sums the nonzero cells into
    /// bucket row offsets, and counting-sort scatters sources into rows.
    /// Pass 2 fills the SoA planes over the canonical chunk grid — serial
    /// or under `std::thread::scope`, bit-identically either way, because
    /// tasks own disjoint row ranges and threads race only to claim them.
    pub fn build_opts(
        m: &BlockedMatrix,
        cost: &[f32],
        src_lo: usize,
        src_hi: usize,
        kind_of: &dyn Fn(usize) -> ProjectionKind,
        opts: BuildOptions,
    ) -> Result<SlabLayout, String> {
        assert!(src_lo <= src_hi && src_hi <= m.num_sources);
        assert_eq!(cost.len(), m.nnz());
        let policy = opts.policy;
        let num_slots = policy.widths().len();

        // Pass 1a: classify every source once — the only kind_of calls.
        let mut tags: Vec<Option<(ProjectionKind, usize)>> =
            Vec::with_capacity(src_hi - src_lo);
        for i in src_lo..src_hi {
            let deg = m.degree(i);
            if deg == 0 {
                tags.push(None); // isolated source: no variables
                continue;
            }
            let kind = kind_of(i);
            if deg > MAX_WIDTH && !kind.separable() {
                return Err(format!(
                    "source {i} degree {deg} exceeds MAX_WIDTH {MAX_WIDTH} \
                     for non-separable {} projection",
                    kind.name()
                ));
            }
            tags.push(Some((kind, policy.slot_for(deg))));
        }

        // Distinct kinds, ascending — the bucket-major order (`Ord` on
        // ProjectionKind matches the serial build's historical (kind,
        // width) grouping order, so pow2 layouts are bit-compatible).
        let mut kinds: Vec<ProjectionKind> =
            tags.iter().flatten().map(|&(k, _)| k).collect();
        kinds.sort_unstable();
        kinds.dedup();

        // Pass 1b: dense (kind × width-slot) row counters. Over-wide
        // separable sources occupy one row per MAX_WIDTH-sized piece.
        let mut counts = vec![0usize; kinds.len() * num_slots];
        for (o, tag) in tags.iter().enumerate() {
            if let Some((kind, slot)) = *tag {
                let deg = m.degree(src_lo + o);
                let copies = if deg > MAX_WIDTH { deg.div_ceil(MAX_WIDTH) } else { 1 };
                counts[kind_index(&kinds, kind) * num_slots + slot] += copies;
            }
        }

        // Pass 1c: prefix-sum the nonzero cells, in ascending (kind,
        // slot) code order, into bucket row offsets.
        struct Cell {
            kind: ProjectionKind,
            width: usize,
            rows: usize,
            row_base: usize,
        }
        let mut cells: Vec<Cell> = Vec::new();
        let mut bucket_of = vec![usize::MAX; counts.len()];
        let mut total_rows = 0usize;
        for (code, &rows) in counts.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            bucket_of[code] = cells.len();
            cells.push(Cell {
                kind: kinds[code / num_slots],
                width: policy.widths()[code % num_slots],
                rows,
                row_base: total_rows,
            });
            total_rows += rows;
        }

        // Pass 1d: counting-sort scatter — the inverted source→row map.
        // Ascending source order keeps each bucket's `sources` sorted
        // with split copies contiguous, exactly the serial fill order.
        let mut row_src = vec![0u32; total_rows];
        let mut cursor: Vec<usize> = cells.iter().map(|c| c.row_base).collect();
        for (o, tag) in tags.iter().enumerate() {
            if let Some((kind, slot)) = *tag {
                let i = src_lo + o;
                let deg = m.degree(i);
                let copies = if deg > MAX_WIDTH { deg.div_ceil(MAX_WIDTH) } else { 1 };
                let b = bucket_of[kind_index(&kinds, kind) * num_slots + slot];
                for r in 0..copies {
                    row_src[cursor[b] + r] = i as u32;
                }
                cursor[b] += copies;
            }
        }

        // Pass 1e: bucket shells — padding-state planes plus `row_len`.
        let mut buckets: Vec<Bucket> = cells
            .iter()
            .map(|c| {
                let srcs = row_src[c.row_base..c.row_base + c.rows].to_vec();
                bucket_shell(c.kind, c.width, srcs, m)
            })
            .collect();

        // Pass 2: carve one fill task per canonical grid chunk. Tasks are
        // disjoint row ranges, so any claim order yields identical bytes.
        let target = chunk_target(total_rows);
        let mut tasks: Vec<Mutex<FillTask<'_>>> = Vec::new();
        for bk in buckets.iter_mut() {
            let w = bk.width;
            let Bucket { sources, dest_idx, edge_id, cost: bcost, a, mask, .. } = bk;
            let sources: &[u32] = sources;
            let mut dest_rest: &mut [u32] = dest_idx;
            let mut edge_rest: &mut [u32] = edge_id;
            let mut cost_rest: &mut [f32] = bcost;
            let mut a_rest: Vec<&mut [f32]> =
                a.iter_mut().map(|p| p.as_mut_slice()).collect();
            let mut mask_rest: &mut [f32] = mask;
            for (lo, hi) in bucket_chunks(sources.len(), target) {
                let n = (hi - lo) * w;
                tasks.push(Mutex::new(FillTask {
                    width: w,
                    run0: split_run(sources, lo),
                    sources: &sources[lo..hi],
                    dest_idx: carve(&mut dest_rest, n),
                    edge_id: carve(&mut edge_rest, n),
                    cost: carve(&mut cost_rest, n),
                    a: a_rest.iter_mut().map(|p| carve(p, n)).collect(),
                    mask: carve(&mut mask_rest, n),
                }));
            }
        }
        let threads = if opts.threads > 1 { opts.threads.min(tasks.len()) } else { 1 };
        if threads <= 1 {
            for t in &tasks {
                let mut task = t.lock().unwrap_or_else(|e| e.into_inner());
                fill_task(&mut task, m, cost);
            }
        } else {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let mut task =
                            tasks[i].lock().unwrap_or_else(|e| e.into_inner());
                        fill_task(&mut task, m, cost);
                    });
                }
            });
        }
        drop(tasks);

        Ok(SlabLayout {
            buckets,
            num_families: m.num_families,
            num_dests: m.num_dests,
            policy,
        })
    }

    pub fn total_rows(&self) -> usize {
        self.buckets.iter().map(|b| b.rows()).sum()
    }

    pub fn total_real_edges(&self) -> usize {
        self.buckets.iter().map(|b| b.real_edges()).sum()
    }

    pub fn total_padded_edges(&self) -> usize {
        self.buckets.iter().map(|b| b.padded_edges()).sum()
    }

    /// Padding overhead factor (paper: < 2 within each pow2 bucket).
    pub fn padding_factor(&self) -> f64 {
        self.total_padded_edges() as f64 / self.total_real_edges().max(1) as f64
    }

    /// Per-bucket padding breakdown under the active [`WidthPolicy`].
    pub fn padding_report(&self) -> Vec<BucketPadding> {
        self.buckets
            .iter()
            .map(|b| BucketPadding {
                kind: b.kind.name().to_string(),
                width: b.width,
                rows: b.rows(),
                real_edges: b.real_edges(),
                padded_edges: b.padded_edges(),
                factor: b.padded_edges() as f64 / b.real_edges().max(1) as f64,
            })
            .collect()
    }

    /// Number of kernel launches per iteration under this layout
    /// (paper: 1 + ⌊log₂ s_max⌋ per kind under pow2 widths).
    pub fn num_launches(&self) -> usize {
        self.buckets.len()
    }

    /// The canonical fixed chunk grid over this layout: each bucket's rows
    /// cut into ranges of a target size derived from the layout alone
    /// (`total_rows / MAX_CHUNKS`, floored at `MIN_CHUNK_ROWS`). Every
    /// consumer of the layout — the slab objective's thread pool, the
    /// sharded backend, the distributed worker pool — must use THIS grid:
    /// per-chunk partial reductions merged in ascending grid index are the
    /// definition of the layout's bit-exact evaluation order.
    pub fn fixed_chunk_grid(&self) -> Vec<SlabChunk> {
        let target = chunk_target(self.total_rows());
        let mut grid = Vec::new();
        for (b, bk) in self.buckets.iter().enumerate() {
            for (lo, hi) in bucket_chunks(bk.rows(), target) {
                grid.push(SlabChunk { bucket: b, row_lo: lo, row_hi: hi });
            }
        }
        grid
    }

    /// Real (non-padding) edges inside one chunk — an O(rows) `row_len`
    /// prefix sum (build time stores per-row lengths precisely so
    /// partition/repack time never rescans masks).
    pub fn chunk_real_edges(&self, c: &SlabChunk) -> usize {
        self.buckets[c.bucket].row_len[c.row_lo..c.row_hi]
            .iter()
            .map(|&l| l as usize)
            .sum::<usize>()
    }

    /// Cumulative real-edge pointer over a chunk grid — the `src_ptr`
    /// analogue that `distributed::balanced_partition` consumes to cut
    /// the grid into contiguous shard ranges balanced by **real** edge
    /// count (padding is free to evaluate relative to real work and must
    /// not skew the split).
    pub fn chunk_edge_ptr(&self, grid: &[SlabChunk]) -> Vec<usize> {
        let mut ptr = Vec::with_capacity(grid.len() + 1);
        ptr.push(0usize);
        for c in grid {
            ptr.push(ptr.last().unwrap() + self.chunk_real_edges(c));
        }
        ptr
    }

    /// Rewrite the cost plane in place from a perturbed per-edge cost
    /// vector (global edge indexing) — the c-delta path. Structure (edge
    /// pattern, a-planes, masks, grid) is untouched, so this never
    /// invalidates anything derived from the layout. Only real entries
    /// are visited (`row_len` prefixes), never padding.
    pub fn patch_costs(&mut self, cost: &[f32]) {
        for bk in &mut self.buckets {
            let w = bk.width;
            for (row, &len) in bk.row_len.iter().enumerate() {
                let base = row * w;
                for col in 0..len as usize {
                    let e = bk.edge_id[base + col] as usize;
                    bk.cost[base + col] = cost[e];
                }
            }
        }
    }

    /// Plane-by-plane bit equality with `other` — the parity gate shared
    /// by the serve audit, the proptests, and the build bench.
    pub fn bit_eq(&self, other: &SlabLayout) -> Result<(), String> {
        if self.num_families != other.num_families || self.num_dests != other.num_dests {
            return Err("layout dimensions diverge".into());
        }
        if self.policy != other.policy {
            return Err(format!(
                "width policy diverges: {} vs {}",
                self.policy.name(),
                other.policy.name()
            ));
        }
        if self.buckets.len() != other.buckets.len() {
            return Err(format!(
                "bucket count diverges: {} vs {}",
                self.buckets.len(),
                other.buckets.len()
            ));
        }
        for (i, (x, y)) in self.buckets.iter().zip(&other.buckets).enumerate() {
            if x.kind != y.kind || x.width != y.width {
                return Err(format!("bucket {i} shape diverges"));
            }
            if x.sources != y.sources {
                return Err(format!("bucket {i} sources diverge"));
            }
            if x.row_len != y.row_len {
                return Err(format!("bucket {i} row lengths diverge"));
            }
            if x.dest_idx != y.dest_idx || x.edge_id != y.edge_id {
                return Err(format!("bucket {i} index planes diverge"));
            }
            if x.real_edge_count != y.real_edge_count {
                return Err(format!("bucket {i} real edge count diverges"));
            }
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            if bits(&x.cost) != bits(&y.cost) || bits(&x.mask) != bits(&y.mask) {
                return Err(format!("bucket {i} value planes diverge"));
            }
            for k in 0..x.a.len() {
                if bits(&x.a[k]) != bits(&y.a[k]) {
                    return Err(format!("bucket {i} family {k} plane diverges"));
                }
            }
        }
        Ok(())
    }

    /// Shift stored global edge ids after a CSR splice: ids `>= from` move
    /// by `delta` (+1 after an insert at `from`, −1 after a delete, where
    /// the deleted id itself lives in the edited source's row and is
    /// rewritten by the caller).
    fn renumber_edges(&mut self, from: u32, delta: i32) {
        for bk in &mut self.buckets {
            for eid in &mut bk.edge_id {
                if *eid != u32::MAX && *eid >= from {
                    *eid = eid.wrapping_add(delta as u32);
                }
            }
        }
    }

    /// Rewrite one bucket row from the (post-edit) matrix: the in-place
    /// fast path of `patch_edge`, valid only when the source occupies a
    /// single row and its new degree still fits the bucket width.
    fn refill_row(&mut self, bucket: usize, row: usize, m: &BlockedMatrix, cost: &[f32]) {
        let bk = &mut self.buckets[bucket];
        let w = bk.width;
        let base = row * w;
        let i = bk.sources[row] as usize;
        let (e0, e1) = (m.src_ptr[i], m.src_ptr[i + 1]);
        let deg = e1 - e0;
        debug_assert!(deg <= w);
        let old_real = bk.row_len[row] as usize;
        for col in 0..w {
            if col < deg {
                let e = e0 + col;
                bk.dest_idx[base + col] = m.dest_idx[e];
                bk.edge_id[base + col] = e as u32;
                bk.cost[base + col] = cost[e];
                for k in 0..m.num_families {
                    bk.a[k][base + col] = m.a[k][e];
                }
                bk.mask[base + col] = 1.0;
            } else {
                bk.dest_idx[base + col] = 0;
                bk.edge_id[base + col] = u32::MAX;
                bk.cost[base + col] = 0.0;
                for k in 0..m.num_families {
                    bk.a[k][base + col] = 0.0;
                }
                bk.mask[base + col] = 0.0;
            }
        }
        bk.row_len[row] = deg as u16;
        bk.real_edge_count = bk.real_edge_count + deg - old_real;
    }

    /// Remove rows `[row_lo, row_hi)` of bucket `bi` (a drained source's
    /// copies). The surviving rows' bytes are already correct — edge ids
    /// were renumbered up front — so no refill is needed for parity with
    /// a fresh build.
    fn drain_rows(&mut self, bi: usize, row_lo: usize, row_hi: usize) {
        let bk = &mut self.buckets[bi];
        let w = bk.width;
        let removed = bk.row_len[row_lo..row_hi]
            .iter()
            .map(|&l| l as usize)
            .sum::<usize>();
        bk.sources.drain(row_lo..row_hi);
        bk.row_len.drain(row_lo..row_hi);
        bk.dest_idx.drain(row_lo * w..row_hi * w);
        bk.edge_id.drain(row_lo * w..row_hi * w);
        bk.cost.drain(row_lo * w..row_hi * w);
        for plane in &mut bk.a {
            plane.drain(row_lo * w..row_hi * w);
        }
        bk.mask.drain(row_lo * w..row_hi * w);
        bk.real_edge_count -= removed;
    }

    /// Splice `copies` fresh rows for `source` into bucket `bi` at its
    /// sorted position and fill them from the matrix through the shared
    /// row primitive — the bucket ends bit-identical to a fresh build.
    fn insert_rows(
        &mut self,
        bi: usize,
        source: usize,
        copies: usize,
        m: &BlockedMatrix,
        cost: &[f32],
    ) {
        let deg = m.degree(source);
        let bk = &mut self.buckets[bi];
        let w = bk.width;
        let at = bk.sources.partition_point(|&s| s < source as u32);
        bk.sources
            .splice(at..at, std::iter::repeat_n(source as u32, copies));
        bk.row_len
            .splice(at..at, (0..copies).map(|r| ((deg - r * w).min(w)) as u16));
        bk.dest_idx
            .splice(at * w..at * w, std::iter::repeat_n(0u32, copies * w));
        bk.edge_id
            .splice(at * w..at * w, std::iter::repeat_n(u32::MAX, copies * w));
        bk.cost
            .splice(at * w..at * w, std::iter::repeat_n(0.0f32, copies * w));
        for plane in &mut bk.a {
            plane.splice(at * w..at * w, std::iter::repeat_n(0.0f32, copies * w));
        }
        bk.mask
            .splice(at * w..at * w, std::iter::repeat_n(0.0f32, copies * w));
        bk.real_edge_count += deg;
        fill_bucket_rows(bk, at, at + copies, m, cost);
    }

    /// Shared precondition gate of the patch paths — an error must leave
    /// the resident layout exactly as it was.
    fn patch_precheck(
        &self,
        m: &BlockedMatrix,
        cost: &[f32],
        source: usize,
        kind: ProjectionKind,
    ) -> Result<(), String> {
        assert_eq!(cost.len(), m.nnz());
        assert_eq!(m.num_families, self.num_families);
        let new_deg = m.degree(source);
        if new_deg > MAX_WIDTH && !kind.separable() {
            return Err(format!(
                "source {source} degree {new_deg} exceeds MAX_WIDTH {MAX_WIDTH} \
                 for non-separable {} projection",
                kind.name()
            ));
        }
        Ok(())
    }

    /// Locate `source`'s rows by scanning bucket source lists — the
    /// index-free fallback (all rows sit in one bucket: kind is fixed per
    /// source and width is a function of its degree). Returns
    /// (bucket, first row, row count).
    fn scan_source(&self, source: usize) -> Option<(usize, usize, usize)> {
        self.buckets.iter().enumerate().find_map(|(bi, bk)| {
            let lo = bk.sources.partition_point(|&s| s < source as u32);
            let hi = bk.sources.partition_point(|&s| s <= source as u32);
            (lo < hi).then_some((bi, lo, hi - lo))
        })
    }

    /// The patch body shared by [`Self::patch_edge`] and
    /// [`Self::patch_edge_indexed`]: `old` is the source's pre-edit
    /// location, preconditions already checked.
    #[allow(clippy::too_many_arguments)]
    fn patch_edge_core(
        &mut self,
        m: &BlockedMatrix,
        cost: &[f32],
        source: usize,
        edge: usize,
        insert: bool,
        kind: ProjectionKind,
        old: Option<(usize, usize, usize)>,
    ) -> (EdgePatch, PatchTouch) {
        let new_deg = m.degree(source);
        if insert {
            self.renumber_edges(edge as u32, 1);
        } else {
            self.renumber_edges(edge as u32 + 1, -1);
        }

        // In-place fast path: same bucket, one row, degree still fits.
        if let Some((bi, row, rows)) = old {
            if rows == 1
                && new_deg > 0
                && new_deg <= MAX_WIDTH
                && self.buckets[bi].kind == kind
                && self.buckets[bi].width == self.policy.width_for(new_deg)
            {
                self.refill_row(bi, row, m, cost);
                return (EdgePatch::InPlace, PatchTouch::None);
            }
        }

        // Repack: drain the source's rows, splice fresh rows back at its
        // new (kind, width) position. Buckets stay in build order and
        // only the spliced row ranges are refilled, so plane parity with
        // a fresh build is preserved.
        let mut touched: Vec<usize> = Vec::new();
        let mut reshaped = false;
        let mut drained = None;
        if let Some((bi, row, rows)) = old {
            if self.buckets[bi].rows() == rows {
                self.buckets.remove(bi);
                reshaped = true;
            } else {
                self.drain_rows(bi, row, row + rows);
                drained = Some(bi);
            }
        }
        if new_deg > 0 {
            // overwide + non-separable was rejected up front
            let (width, copies) = if new_deg > MAX_WIDTH {
                (MAX_WIDTH, new_deg.div_ceil(MAX_WIDTH))
            } else {
                (self.policy.width_for(new_deg), 1)
            };
            match self
                .buckets
                .binary_search_by(|b| (b.kind, b.width).cmp(&(kind, width)))
            {
                Ok(bi) => {
                    self.insert_rows(bi, source, copies, m, cost);
                    touched.push(bi);
                }
                Err(bi) => {
                    let mut bk =
                        bucket_shell(kind, width, vec![source as u32; copies], m);
                    fill_bucket_rows(&mut bk, 0, copies, m, cost);
                    self.buckets.insert(bi, bk);
                    reshaped = true;
                }
            }
        }
        if let Some(bi) = drained {
            touched.push(bi);
        }
        let touch = if reshaped {
            PatchTouch::Reshaped
        } else {
            PatchTouch::Buckets(touched)
        };
        (EdgePatch::Repacked, touch)
    }

    /// Apply one edge insert or delete to the resident layout.
    ///
    /// `m`/`cost` are the POST-edit matrix and cost planes; `edge` is the
    /// spliced global position (the new edge's index after an insert, the
    /// removed edge's old index after a delete); `source` is the edited
    /// source block and `kind` its projection kind. The patched layout is
    /// bit-identical — plane by plane, bucket by bucket — to
    /// [`Self::build_opts`] of the post-edit matrix under the same
    /// [`WidthPolicy`] (the parity gate the serve tests assert), without
    /// ever re-laying-out untouched sources:
    ///
    /// 1. a renumber sweep shifts stored edge ids past the splice point,
    /// 2. if the source keeps its (kind, width) bucket and occupies one
    ///    row, that row alone is rewritten using the padding headroom
    ///    ([`EdgePatch::InPlace`]),
    /// 3. otherwise the source's rows are drained and fresh rows spliced
    ///    in at the new (kind, width) position (buckets created/removed
    ///    as needed, in build order) and refilled through the shared fill
    ///    primitive; the caller must refresh its chunk grid
    ///    ([`EdgePatch::Repacked`]).
    pub fn patch_edge(
        &mut self,
        m: &BlockedMatrix,
        cost: &[f32],
        source: usize,
        edge: usize,
        insert: bool,
        kind: ProjectionKind,
    ) -> Result<EdgePatch, String> {
        self.patch_precheck(m, cost, source, kind)?;
        let old = self.scan_source(source);
        let (patch, _) = self.patch_edge_core(m, cost, source, edge, insert, kind, old);
        Ok(patch)
    }

    /// [`Self::patch_edge`] with O(1) source location through a resident
    /// [`SlabIndex`], kept in sync incrementally: in-place patches touch
    /// nothing, bucket-preserving repacks reindex only the touched
    /// buckets, and bucket creation/removal rebuilds the index.
    #[allow(clippy::too_many_arguments)]
    pub fn patch_edge_indexed(
        &mut self,
        m: &BlockedMatrix,
        cost: &[f32],
        source: usize,
        edge: usize,
        insert: bool,
        kind: ProjectionKind,
        index: &mut SlabIndex,
    ) -> Result<EdgePatch, String> {
        self.patch_precheck(m, cost, source, kind)?;
        let old = index.locate(source);
        debug_assert_eq!(old, self.scan_source(source), "stale slab index");
        let (patch, touch) = self.patch_edge_core(m, cost, source, edge, insert, kind, old);
        match touch {
            PatchTouch::None => {}
            PatchTouch::Buckets(bis) => {
                index.clear(source);
                for bi in bis {
                    index.reindex_bucket(self, bi);
                }
            }
            PatchTouch::Reshaped => {
                *index =
                    SlabIndex::build(self, index.src_lo, index.src_lo + index.num_sources());
            }
        }
        Ok(patch)
    }
}

const NO_BUCKET: u32 = u32::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct IndexEntry {
    bucket: u32,
    first_row: u32,
    rows: u32,
}

/// Inverted source→row index over a [`SlabLayout`]: for each source in
/// `[src_lo, src_hi)`, which bucket holds it and which contiguous row
/// range (split separable sources span several rows). Retained by the
/// serve path so edge deltas locate rows in O(1) instead of scanning
/// every bucket's source list.
#[derive(Clone, Debug)]
pub struct SlabIndex {
    src_lo: usize,
    entries: Vec<IndexEntry>,
}

impl SlabIndex {
    /// Index `layout` for sources `[src_lo, src_hi)` — one O(total rows)
    /// sweep over the bucket source lists.
    pub fn build(layout: &SlabLayout, src_lo: usize, src_hi: usize) -> SlabIndex {
        let mut ix = SlabIndex {
            src_lo,
            entries: vec![
                IndexEntry { bucket: NO_BUCKET, first_row: 0, rows: 0 };
                src_hi - src_lo
            ],
        };
        for bi in 0..layout.buckets.len() {
            ix.reindex_bucket(layout, bi);
        }
        ix
    }

    /// Number of sources covered by this index.
    pub fn num_sources(&self) -> usize {
        self.entries.len()
    }

    /// (bucket, first row, row count) of `source`, or None if it holds no
    /// edges. O(1).
    pub fn locate(&self, source: usize) -> Option<(usize, usize, usize)> {
        let e = source.checked_sub(self.src_lo).and_then(|o| self.entries.get(o))?;
        (e.bucket != NO_BUCKET)
            .then_some((e.bucket as usize, e.first_row as usize, e.rows as usize))
    }

    /// Forget `source` (it left the layout).
    fn clear(&mut self, source: usize) {
        if let Some(e) = source
            .checked_sub(self.src_lo)
            .and_then(|o| self.entries.get_mut(o))
        {
            *e = IndexEntry { bucket: NO_BUCKET, first_row: 0, rows: 0 };
        }
    }

    /// Re-derive every entry that points into bucket `bi` — a run sweep
    /// over its (sorted, split-contiguous) source list.
    fn reindex_bucket(&mut self, layout: &SlabLayout, bi: usize) {
        let sources = &layout.buckets[bi].sources;
        let mut r = 0usize;
        while r < sources.len() {
            let src = sources[r];
            let mut hi = r + 1;
            while hi < sources.len() && sources[hi] == src {
                hi += 1;
            }
            if let Some(e) = (src as usize)
                .checked_sub(self.src_lo)
                .and_then(|o| self.entries.get_mut(o))
            {
                *e = IndexEntry {
                    bucket: bi as u32,
                    first_row: r as u32,
                    rows: (hi - r) as u32,
                };
            }
            r = hi;
        }
    }

    /// Assert the resident index matches a from-scratch rebuild over
    /// `layout` — the serve-path audit hook.
    pub fn parity_check(&self, layout: &SlabLayout) -> Result<(), String> {
        let fresh = SlabIndex::build(layout, self.src_lo, self.src_lo + self.entries.len());
        for (o, (a, b)) in self.entries.iter().zip(&fresh.entries).enumerate() {
            if a != b {
                return Err(format!(
                    "slab index divergence at source {}: resident {:?} vs rebuilt {:?}",
                    self.src_lo + o,
                    a,
                    b
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(degrees: &[usize], num_dests: usize) -> (BlockedMatrix, Vec<f32>) {
        let mut src_ptr = vec![0usize];
        let mut dest_idx = Vec::new();
        for &d in degrees {
            for j in 0..d {
                dest_idx.push((j % num_dests) as u32);
            }
            src_ptr.push(dest_idx.len());
        }
        let nnz = dest_idx.len();
        let a = vec![(0..nnz).map(|e| 1.0 + e as f32 * 0.1).collect()];
        let cost = (0..nnz).map(|e| -(e as f32) * 0.01 - 0.1).collect();
        (
            BlockedMatrix {
                num_sources: degrees.len(),
                num_dests,
                num_families: 1,
                src_ptr,
                dest_idx,
                a,
            },
            cost,
        )
    }

    #[test]
    fn bucket_width_pow2() {
        assert_eq!(bucket_width(1), MIN_WIDTH);
        assert_eq!(bucket_width(4), 4);
        assert_eq!(bucket_width(5), 8);
        assert_eq!(bucket_width(8), 8);
        assert_eq!(bucket_width(9), 16);
        assert_eq!(bucket_width(4000), MAX_WIDTH);
    }

    #[test]
    fn quarter_step_widths_between_pow2() {
        let q = WidthPolicy::QuarterStep;
        assert_eq!(q.width_for(9), 12);
        assert_eq!(q.width_for(12), 12);
        assert_eq!(q.width_for(13), 16);
        assert_eq!(q.width_for(17), 24);
        assert_eq!(q.width_for(400), 512);
        assert_eq!(q.width_for(4000), MAX_WIDTH);
        for d in 1..=MAX_WIDTH {
            assert_eq!(WidthPolicy::Pow2.width_for(d), bucket_width(d), "deg {d}");
            let w = q.width_for(d);
            assert!(w >= d && w <= bucket_width(d), "deg {d}: quarter width {w}");
        }
        assert_eq!(WidthPolicy::parse("pow2"), Some(WidthPolicy::Pow2));
        assert_eq!(WidthPolicy::parse("quarter"), Some(WidthPolicy::QuarterStep));
        assert_eq!(WidthPolicy::parse("quarter-step"), Some(WidthPolicy::QuarterStep));
        assert_eq!(WidthPolicy::parse("pow3"), None);
    }

    #[test]
    fn builds_buckets_by_log2_degree() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let l = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        let widths: Vec<usize> = l.buckets.iter().map(|b| b.width).collect();
        assert_eq!(widths, vec![4, 8, 16, 32]);
        // w=4 bucket has sources 0 (deg3), 1 (deg4), 5 (deg2)
        assert_eq!(l.buckets[0].sources, vec![0, 1, 5]);
        assert_eq!(l.total_rows(), 6);
        assert_eq!(l.total_real_edges(), 3 + 4 + 5 + 9 + 17 + 2);
    }

    #[test]
    fn padding_factor_below_two() {
        let degrees: Vec<usize> = (1..200).collect();
        let (m, cost) = matrix(&degrees, 256);
        let l = SlabLayout::build(&m, &cost, 0, degrees.len(), &|_| ProjectionKind::Box).unwrap();
        assert!(l.padding_factor() < 2.3, "factor={}", l.padding_factor());
        // and launches bounded by kinds × widths
        assert!(l.num_launches() <= 1 + (256f64).log2() as usize);
    }

    #[test]
    fn quarter_step_reduces_padding_on_skewed_degrees() {
        // degrees just past a pow2 boundary: the adversarial case for
        // pow2 bucketing, the motivating case for quarter steps
        let degrees: Vec<usize> = (0..200).map(|i| 9 + i % 4).collect();
        let (m, cost) = matrix(&degrees, 16);
        let kind_of = |_: usize| ProjectionKind::Simplex;
        let pow2 =
            SlabLayout::build_opts(&m, &cost, 0, 200, &kind_of, BuildOptions::default())
                .unwrap();
        let quarter = SlabLayout::build_opts(
            &m,
            &cost,
            0,
            200,
            &kind_of,
            BuildOptions { policy: WidthPolicy::QuarterStep, threads: 0 },
        )
        .unwrap();
        assert_eq!(quarter.total_real_edges(), pow2.total_real_edges());
        assert!(
            quarter.padding_factor() < pow2.padding_factor(),
            "quarter {} !< pow2 {}",
            quarter.padding_factor(),
            pow2.padding_factor()
        );
        let report = quarter.padding_report();
        assert_eq!(
            report.iter().map(|b| b.real_edges).sum::<usize>(),
            quarter.total_real_edges()
        );
        for b in &report {
            assert!(b.factor >= 1.0);
        }
    }

    #[test]
    fn parallel_build_bit_identical_to_serial() {
        let mut degrees: Vec<usize> = (0..300).map(|i| (i * 7) % 40).collect();
        degrees.push(MAX_WIDTH + 10); // split separable source
        degrees.push(0);
        degrees.push(2 * MAX_WIDTH + 300);
        let n = degrees.len();
        let (m, cost) = matrix(&degrees, MAX_WIDTH + 16);
        let degs = degrees.clone();
        let kind_of = move |i: usize| {
            if degs[i] > MAX_WIDTH || i % 3 == 0 {
                ProjectionKind::Box
            } else {
                ProjectionKind::Simplex
            }
        };
        for policy in [WidthPolicy::Pow2, WidthPolicy::QuarterStep] {
            let serial = SlabLayout::build_opts(
                &m,
                &cost,
                0,
                n,
                &kind_of,
                BuildOptions { policy, threads: 0 },
            )
            .unwrap();
            if policy == WidthPolicy::Pow2 {
                // pow2 serial == the legacy build entry point, bit for bit
                let legacy = SlabLayout::build(&m, &cost, 0, n, &kind_of).unwrap();
                assert_layout_bit_eq(&serial, &legacy);
            }
            for threads in [1, 2, 4, 8] {
                let par = SlabLayout::build_opts(
                    &m,
                    &cost,
                    0,
                    n,
                    &kind_of,
                    BuildOptions { policy, threads },
                )
                .unwrap();
                assert_layout_bit_eq(&par, &serial);
            }
        }
    }

    #[test]
    fn slab_contents_match_matrix() {
        let (m, cost) = matrix(&[3, 4], 8);
        let l = SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Simplex).unwrap();
        let b = &l.buckets[0];
        assert_eq!(b.width, 4);
        assert_eq!(b.rows(), 2);
        // row 0 = source 0 (deg 3): 3 real + 1 pad
        assert_eq!(&b.mask[0..4], &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.dest_idx[0..3], m.dest_idx[0..3]);
        assert_eq!(b.cost[0..3], cost[0..3]);
        assert_eq!(b.a[0][0..3], m.a[0][0..3]);
        // padding carries zeros
        assert_eq!(b.cost[3], 0.0);
        assert_eq!(b.a[0][3], 0.0);
        assert_eq!(b.row_len, vec![3, 4]);
    }

    #[test]
    fn shard_ranges_partition_edges() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let full = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Box).unwrap();
        let a = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Box).unwrap();
        let b = SlabLayout::build(&m, &cost, 3, 6, &|_| ProjectionKind::Box).unwrap();
        assert_eq!(
            full.total_real_edges(),
            a.total_real_edges() + b.total_real_edges()
        );
    }

    #[test]
    fn simplex_rejects_overwide_source() {
        let (m, cost) = matrix(&[MAX_WIDTH + 1], MAX_WIDTH + 2);
        let err = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Simplex);
        assert!(err.is_err());
    }

    #[test]
    fn box_splits_overwide_source() {
        let deg = MAX_WIDTH + 10;
        let (m, cost) = matrix(&[deg], MAX_WIDTH + 16);
        let l = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Box).unwrap();
        assert_eq!(l.total_real_edges(), deg);
        assert_eq!(l.total_rows(), 2); // split into two rows
        assert_eq!(l.buckets[0].sources, vec![0, 0]);
        assert_eq!(l.buckets[0].row_len, vec![MAX_WIDTH as u16, 10]);
    }

    #[test]
    fn mixed_projection_kinds_bucket_separately() {
        let (m, cost) = matrix(&[3, 3, 3, 3], 8);
        let l = SlabLayout::build(&m, &cost, 0, 4, &|i| {
            if i % 2 == 0 { ProjectionKind::Simplex } else { ProjectionKind::Box }
        })
        .unwrap();
        assert_eq!(l.num_launches(), 2);
        let kinds: Vec<_> = l.buckets.iter().map(|b| b.kind).collect();
        assert!(kinds.contains(&ProjectionKind::Simplex));
        assert!(kinds.contains(&ProjectionKind::Box));
    }

    #[test]
    fn stored_real_edge_count_matches_mask_scan() {
        let (m, cost) = matrix(&[3, 4, 5, 9, 17, 2, MAX_WIDTH + 10], MAX_WIDTH + 16);
        let l = SlabLayout::build(&m, &cost, 0, 7, &|_| ProjectionKind::Box).unwrap();
        for bk in &l.buckets {
            let scanned = bk.mask.iter().filter(|&&v| v > 0.0).count();
            assert_eq!(bk.real_edges(), scanned);
            let from_rows = bk.row_len.iter().map(|&n| n as usize).sum::<usize>();
            assert_eq!(from_rows, scanned, "row_len inconsistent with mask");
            for (row, &len) in bk.row_len.iter().enumerate() {
                let base = row * bk.width;
                let row_scan = bk.mask[base..base + bk.width]
                    .iter()
                    .filter(|&&v| v > 0.0)
                    .count();
                assert_eq!(len as usize, row_scan, "row {row}");
            }
        }
        assert_eq!(l.total_real_edges(), 3 + 4 + 5 + 9 + 17 + 2 + MAX_WIDTH + 10);
    }

    #[test]
    fn fixed_chunk_grid_covers_rows_in_order() {
        let degrees: Vec<usize> = (1..400).map(|i| 1 + i % 13).collect();
        let (m, cost) = matrix(&degrees, 64);
        let l = SlabLayout::build(&m, &cost, 0, degrees.len(), &|_| ProjectionKind::Box).unwrap();
        let grid = l.fixed_chunk_grid();
        // chunks cover every bucket's rows exactly once, in ascending
        // (bucket, row) order
        let mut covered = 0usize;
        let mut prev: Option<SlabChunk> = None;
        for c in &grid {
            assert!(c.row_lo < c.row_hi);
            if let Some(p) = prev {
                if p.bucket == c.bucket {
                    assert_eq!(p.row_hi, c.row_lo, "gap within bucket");
                } else {
                    assert!(c.bucket > p.bucket, "buckets out of order");
                    assert_eq!(p.row_hi, l.buckets[p.bucket].rows(), "bucket not exhausted");
                    assert_eq!(c.row_lo, 0);
                }
            } else {
                assert_eq!((c.bucket, c.row_lo), (0, 0));
            }
            covered += c.rows();
            prev = Some(*c);
        }
        assert_eq!(covered, l.total_rows());
        // real-edge bookkeeping is consistent with the buckets
        assert_eq!(
            grid.iter().map(|c| l.chunk_real_edges(c)).sum::<usize>(),
            l.total_real_edges()
        );
        let ptr = l.chunk_edge_ptr(&grid);
        assert_eq!(ptr.len(), grid.len() + 1);
        assert_eq!(*ptr.last().unwrap(), l.total_real_edges());
    }

    #[test]
    fn zero_degree_sources_skipped() {
        let (m, cost) = matrix(&[0, 3, 0], 8);
        let l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_eq!(l.total_rows(), 1);
        assert_eq!(l.buckets[0].sources, vec![1]);
    }

    #[test]
    fn slab_index_locates_every_source() {
        let degrees = [3, 0, 9, MAX_WIDTH + 10, 4, 0, 17];
        let (m, cost) = matrix(&degrees, MAX_WIDTH + 16);
        let l = SlabLayout::build(&m, &cost, 0, degrees.len(), &|_| ProjectionKind::Box).unwrap();
        let ix = SlabIndex::build(&l, 0, degrees.len());
        assert_eq!(ix.num_sources(), degrees.len());
        for (i, &d) in degrees.iter().enumerate() {
            let hit = ix.locate(i);
            assert_eq!(hit, l.scan_source(i), "source {i}");
            if d == 0 {
                assert!(hit.is_none());
            } else {
                let (bi, row, rows) = hit.unwrap();
                let copies = if d > MAX_WIDTH { d.div_ceil(MAX_WIDTH) } else { 1 };
                assert_eq!(rows, copies);
                assert_eq!(l.buckets[bi].sources[row], i as u32);
            }
        }
        assert!(ix.locate(degrees.len() + 5).is_none());
        ix.parity_check(&l).unwrap();
    }

    /// Splice one edge into the CSR at the end of `source`'s range,
    /// returning its global position — the test mirror of the serve host's
    /// delta application.
    fn insert_edge(
        m: &mut BlockedMatrix,
        cost: &mut Vec<f32>,
        source: usize,
        dest: u32,
        aval: f32,
        cval: f32,
    ) -> usize {
        let p = m.src_ptr[source + 1];
        m.dest_idx.insert(p, dest);
        for plane in &mut m.a {
            plane.insert(p, aval);
        }
        cost.insert(p, cval);
        for ptr in &mut m.src_ptr[source + 1..] {
            *ptr += 1;
        }
        p
    }

    /// Remove `source`'s `col`-th edge from the CSR, returning its old
    /// global position.
    fn remove_edge(
        m: &mut BlockedMatrix,
        cost: &mut Vec<f32>,
        source: usize,
        col: usize,
    ) -> usize {
        let p = m.src_ptr[source] + col;
        m.dest_idx.remove(p);
        for plane in &mut m.a {
            plane.remove(p);
        }
        cost.remove(p);
        for ptr in &mut m.src_ptr[source + 1..] {
            *ptr -= 1;
        }
        p
    }

    /// Plane-by-plane bit equality — the delta-path parity gate.
    fn assert_layout_bit_eq(a: &SlabLayout, b: &SlabLayout) {
        if let Err(e) = a.bit_eq(b) {
            panic!("layout bit parity: {e}");
        }
    }

    #[test]
    fn patch_costs_matches_rebuild() {
        let (m, mut cost) = matrix(&[3, 4, 5, 9, 17, 2], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        for (e, c) in cost.iter_mut().enumerate() {
            *c += 0.001 * e as f32;
        }
        l.patch_costs(&cost);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 6, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn insert_within_headroom_is_in_place() {
        // source 0 has degree 3 in a width-4 bucket: one edge of headroom
        let (mut m, mut cost) = matrix(&[3, 4, 5], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        let grid_before = l.fixed_chunk_grid();
        let p = insert_edge(&mut m, &mut cost, 0, 30, 2.5, -0.9);
        let patch = l.patch_edge(&m, &cost, 0, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::InPlace);
        assert_eq!(l.fixed_chunk_grid(), grid_before, "in-place keeps the grid");
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn insert_overflowing_bucket_repacks() {
        // source 1 has degree 4 = full width-4 row: the insert overflows
        // into the width-8 bucket (which already holds source 2)
        let (mut m, mut cost) = matrix(&[3, 4, 5], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        let p = insert_edge(&mut m, &mut cost, 1, 31, 1.25, -0.45);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
    }

    #[test]
    fn delete_in_place_and_across_widths() {
        let (mut m, mut cost) = matrix(&[4, 5, 9], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        // 4 → 3 stays in the width-4 bucket
        let p = remove_edge(&mut m, &mut cost, 0, 1);
        let patch = l.patch_edge(&m, &cost, 0, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::InPlace);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        // 5 → 4 crosses width 8 → 4
        let p = remove_edge(&mut m, &mut cost, 1, 0);
        let patch = l.patch_edge(&m, &cost, 1, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
    }

    #[test]
    fn edge_patch_creates_and_removes_sources_and_buckets() {
        // source 1 starts isolated (degree 0); source 2's width-16 bucket
        // exists only because of source 2
        let (mut m, mut cost) = matrix(&[3, 0, 9], 32);
        let mut l = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_eq!(l.num_launches(), 2);
        // 0 → 1: the isolated source enters the width-4 bucket
        let p = insert_edge(&mut m, &mut cost, 1, 7, 0.5, -0.2);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        assert_eq!(l.buckets[0].sources, vec![0, 1]);
        // 1 → 0: and leaves it again
        let p = remove_edge(&mut m, &mut cost, 1, 0);
        let patch = l.patch_edge(&m, &cost, 1, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap(),
        );
        // 9 → 8 (width 16 → 8): the width-16 bucket disappears entirely
        let p = remove_edge(&mut m, &mut cost, 2, 4);
        let patch = l.patch_edge(&m, &cost, 2, p, false, ProjectionKind::Simplex).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        let rebuilt = SlabLayout::build(&m, &cost, 0, 3, &|_| ProjectionKind::Simplex).unwrap();
        assert_layout_bit_eq(&l, &rebuilt);
        assert!(l.buckets.iter().all(|b| b.width != 16));
    }

    #[test]
    fn split_source_edits_repack_with_parity() {
        let deg = MAX_WIDTH + 10;
        let (mut m, mut cost) = matrix(&[3, deg], MAX_WIDTH + 16);
        let mut l = SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Box).unwrap();
        let p = insert_edge(&mut m, &mut cost, 1, (MAX_WIDTH + 12) as u32, 1.0, -0.3);
        let patch = l.patch_edge(&m, &cost, 1, p, true, ProjectionKind::Box).unwrap();
        assert_eq!(patch, EdgePatch::Repacked);
        assert_layout_bit_eq(
            &l,
            &SlabLayout::build(&m, &cost, 0, 2, &|_| ProjectionKind::Box).unwrap(),
        );
        assert_eq!(l.total_real_edges(), 3 + deg + 1);
    }

    #[test]
    fn indexed_patch_keeps_index_and_layout_parity() {
        for policy in [WidthPolicy::Pow2, WidthPolicy::QuarterStep] {
            let (mut m, mut cost) =
                matrix(&[3, 4, 0, 9, MAX_WIDTH + 10, 5], MAX_WIDTH + 16);
            let opts = BuildOptions { policy, threads: 0 };
            let kind_of = |_: usize| ProjectionKind::Box;
            let mut l = SlabLayout::build_opts(&m, &cost, 0, 6, &kind_of, opts).unwrap();
            let mut ix = SlabIndex::build(&l, 0, 6);
            let check = |l: &SlabLayout, ix: &SlabIndex, m: &BlockedMatrix, cost: &[f32]| {
                let fresh = SlabLayout::build_opts(m, cost, 0, 6, &kind_of, opts).unwrap();
                assert_layout_bit_eq(l, &fresh);
                ix.parity_check(l).unwrap();
            };
            // headroom insert: in-place, index untouched
            let p = insert_edge(&mut m, &mut cost, 0, 30, 2.5, -0.9);
            let patch = l
                .patch_edge_indexed(&m, &cost, 0, p, true, ProjectionKind::Box, &mut ix)
                .unwrap();
            assert_eq!(patch, EdgePatch::InPlace);
            check(&l, &ix, &m, &cost);
            // width-crossing insert: bucket-preserving or reshaping repack
            let p = insert_edge(&mut m, &mut cost, 1, 31, 1.25, -0.45);
            let patch = l
                .patch_edge_indexed(&m, &cost, 1, p, true, ProjectionKind::Box, &mut ix)
                .unwrap();
            assert_eq!(patch, EdgePatch::Repacked);
            check(&l, &ix, &m, &cost);
            // isolated source enters a bucket
            let p = insert_edge(&mut m, &mut cost, 2, 7, 0.5, -0.2);
            l.patch_edge_indexed(&m, &cost, 2, p, true, ProjectionKind::Box, &mut ix)
                .unwrap();
            check(&l, &ix, &m, &cost);
            // split source grows by one edge
            let p = insert_edge(&mut m, &mut cost, 4, (MAX_WIDTH + 12) as u32, 1.0, -0.3);
            let patch = l
                .patch_edge_indexed(&m, &cost, 4, p, true, ProjectionKind::Box, &mut ix)
                .unwrap();
            assert_eq!(patch, EdgePatch::Repacked);
            check(&l, &ix, &m, &cost);
            // a source drains to zero edges and leaves the index
            for _ in 0..m.degree(3) {
                let p = remove_edge(&mut m, &mut cost, 3, 0);
                l.patch_edge_indexed(&m, &cost, 3, p, false, ProjectionKind::Box, &mut ix)
                    .unwrap();
                check(&l, &ix, &m, &cost);
            }
            assert!(ix.locate(3).is_none());
        }
    }

    #[test]
    fn patch_rejects_overwide_non_separable() {
        let (mut m, mut cost) = matrix(&[MAX_WIDTH], MAX_WIDTH + 4);
        let mut l = SlabLayout::build(&m, &cost, 0, 1, &|_| ProjectionKind::Simplex).unwrap();
        let p = insert_edge(&mut m, &mut cost, 0, (MAX_WIDTH + 1) as u32, 1.0, -0.1);
        assert!(l.patch_edge(&m, &cost, 0, p, true, ProjectionKind::Simplex).is_err());
    }

    #[test]
    fn patch_report_tallies() {
        let mut r = PatchReport::default();
        r.note(EdgePatch::InPlace);
        r.note(EdgePatch::InPlace);
        r.note(EdgePatch::Repacked);
        r.cost_patches += 1;
        assert_eq!((r.in_place, r.repacked, r.cost_patches), (2, 1, 1));
    }
}
