//! Minimal CSV emission for figure/table data (`results/*.csv`).
//! No quoting subtleties needed: all emitted fields are numbers or plain
//! identifiers.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one row of stringified fields.
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "column count mismatch");
        writeln!(self.w, "{}", fields.join(","))
    }

    /// Convenience: row of f64s, formatted with enough digits.
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let s: Vec<String> = fields.iter().map(|v| format!("{v:.9e}")).collect();
        self.row(&s)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Format a mixed row: helper macro-free builder.
pub fn fields(items: &[&dyn std::fmt::Display]) -> Vec<String> {
    items.iter().map(|v| v.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dualip_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "value"]).unwrap();
            w.row(&fields(&[&1, &2.5])).unwrap();
            w.row_f64(&[2.0, 3.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "iter,value");
        assert_eq!(lines.next().unwrap(), "1,2.5");
        assert!(lines.next().unwrap().starts_with("2.0"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
