//! Dense f32 vector kernels used on the (small, mJ-sized) dual iterates by
//! the optimizer and the collectives. Simple loops — LLVM auto-vectorizes
//! these; keeping them in one place lets the perf pass target them.

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean dot product (f64 accumulation for stability).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// ‖x‖₂ with f64 accumulation.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ‖x − y‖₂.
#[inline]
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Component-wise max(x, 0) in place (projection onto the dual cone λ ≥ 0).
#[inline]
pub fn clamp_nonneg(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ‖max(x, 0)‖₂ — positive-part norm, used for ‖(Ax−b)₊‖ (Lemma A.1).
#[inline]
pub fn pos_norm2(x: &[f32]) -> f64 {
    x.iter()
        .map(|&v| {
            let p = (v as f64).max(0.0);
            p * p
        })
        .sum::<f64>()
        .sqrt()
}

/// out = a + beta*(a - b)  (Nesterov extrapolation), writing into `out`.
#[inline]
pub fn extrapolate(a: &[f32], b: &[f32], beta: f32, out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] + beta * (a[i] - b[i]);
    }
}

/// Element-wise accumulate: y += x (reduction step of the collective).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn dot_and_norms() {
        let x = vec![3.0, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(dist2(&x, &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn clamp_and_posnorm() {
        let mut x = vec![-1.0, 2.0, -3.0, 4.0];
        assert_eq!(pos_norm2(&x), (4.0f64 + 16.0).sqrt());
        clamp_nonneg(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn extrapolate_matches_formula() {
        let a = vec![2.0, 4.0];
        let b = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        extrapolate(&a, &b, 0.5, &mut out);
        assert_eq!(out, vec![2.5, 5.5]);
    }

    #[test]
    fn dot_f64_accumulation_is_stable() {
        // 1e8 copies of 1e-4 summed in f32 would lose precision badly;
        // here just check a moderately adversarial case.
        let x = vec![1e-4f32; 1_000_000];
        let ones = vec![1.0f32; 1_000_000];
        let s = dot(&x, &ones);
        assert!((s - 100.0).abs() < 1e-3, "s={s}");
    }
}
