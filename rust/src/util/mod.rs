//! Shared utilities: seeded RNG + distributions, dense vector kernels,
//! phase timers, CSV emission.

pub mod csv;
pub mod mathvec;
pub mod rng;
pub mod timer;
