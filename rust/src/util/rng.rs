//! Seeded pseudo-random number generation and the distributions the
//! Appendix-B synthetic workload generator needs (uniform, normal,
//! lognormal, Poisson).
//!
//! Implemented from scratch (no `rand` crate offline): xoshiro256++ for the
//! core stream (seeded via SplitMix64), Box–Muller for normals, and
//! Knuth / PTRS for Poisson. Deterministic across runs and platforms for a
//! given seed — required so "identical problem instances" can be fed to the
//! baseline and the accelerated path (paper §7, fixed random seed).

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per worker / per resource).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift with rejection
    /// (exactly unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64; // 2^64 mod n
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Lognormal with parameters (mu, sigma) of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson(lambda). Knuth's product method for small lambda, PTRS
    /// (transformed rejection) for large — O(1) expected either way.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numeric safety valve
                }
            }
        } else {
            // PTRS (Hörmann 1993)
            let slam = lambda.sqrt();
            let loglam = lambda.ln();
            let b = 0.931 + 2.53 * slam;
            let a = -0.059 + 0.02483 * b;
            let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
            let v_r = 0.9277 - 3.6224 / (b - 2.0);
            loop {
                let u = self.uniform() - 0.5;
                let v = self.uniform();
                let us = 0.5 - u.abs();
                let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
                if us >= 0.07 && v <= v_r {
                    return k as u64;
                }
                if k < 0.0 || (us < 0.013 && v > us) {
                    continue;
                }
                let lhs = (v * inv_alpha / (a / (us * us) + b)).ln();
                let rhs = -lambda + k * loglam - ln_gamma(k + 1.0);
                if lhs <= rhs {
                    return k as u64;
                }
            }
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n). Floyd's algorithm
    /// for k << n, partial shuffle otherwise.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        if k * 4 < n {
            // Floyd's: O(k) expected with a small hash set
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v as u32);
            }
            out
        } else {
            let mut idx: Vec<u32> = (0..n as u32).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
    }
}

/// ln Γ(x) via the Lanczos approximation (g=7, n=9). Good to ~1e-13.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_with_correct_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let mut v: Vec<f64> = (0..50_001).map(|_| r.lognormal(0.7, 0.5)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[25_000];
        // median of lognormal(mu, sigma) = e^mu
        assert!((med - 0.7f64.exp()).abs() / 0.7f64.exp() < 0.05, "med={med}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(17);
        for &lam in &[0.5, 3.0, 25.0, 80.0, 400.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lam) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lam).abs() / lam < 0.05,
                "lambda={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut r = Rng::new(1);
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 5usize), (100, 80), (10, 10), (1000, 1)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-10);
        assert!(ln_gamma(2.0).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
