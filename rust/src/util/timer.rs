//! Lightweight phase timers for per-iteration breakdowns (gather / kernel /
//! scatter / comm / optimizer) reported by the coordinator and benches.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall time per named phase.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimers {
    acc: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.acc.entry(name).or_default() += d;
        *self.counts.entry(name).or_default() += 1;
    }

    pub fn total(&self, name: &str) -> Duration {
        self.acc.get(name).copied().unwrap_or_default()
    }

    pub fn count(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or_default()
    }

    /// Merge another timer set into this one (worker → leader aggregation).
    pub fn merge(&mut self, other: &PhaseTimers) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }

    /// Human-readable one-liner, phases sorted by time desc.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.acc.iter().collect();
        rows.sort_by(|a, b| b.1.cmp(a.1));
        rows.iter()
            .map(|(k, v)| format!("{k}={:.1}ms/{}", v.as_secs_f64() * 1e3, self.counts[*k]))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, *v))
    }
}

/// Per-thread CPU time in milliseconds (CLOCK_THREAD_CPUTIME_ID) —
/// immune to time-slicing with sibling threads on a contended core, so
/// shard evaluation costs measured with it model what dedicated devices
/// would take (DESIGN.md §5 Substitutions).
#[allow(unsafe_code)] // crate-wide #![deny(unsafe_code)]; this is the sole exception
pub fn thread_cpu_time_ms() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: clock_gettime only writes through the valid `&mut ts` for
    // the duration of the call; CLOCK_THREAD_CPUTIME_ID is a constant
    // clock id, and on failure ts stays zeroed (we return 0.0, not junk).
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 * 1e3 + ts.tv_nsec as f64 / 1e6
}

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut t = PhaseTimers::new();
        let v = t.time("a", || 42);
        assert_eq!(v, 42);
        t.time("a", || ());
        t.time("b", || ());
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.count("b"), 1);
        assert!(t.total("a") >= t.total("b"));
        assert!(t.report().contains("a="));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimers::new();
        a.add("x", Duration::from_millis(2));
        let mut b = PhaseTimers::new();
        b.add("x", Duration::from_millis(3));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(5));
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.total("y"), Duration::from_millis(1));
    }
}
