//! Auditor integration gate (DESIGN.md §10): the checked-in tree must
//! audit clean, the fixture self-check must fire exactly the expected
//! rules, and the two rejection paths (unjustified waiver, ratchet
//! increase) must stay closed.

use std::path::Path;

use dualip::analysis::{self, AnalyzedFile, Ratchet};

fn root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn fixtures_fire_exactly_their_rules() {
    let results = analysis::self_check(root()).expect("fixtures present and well-formed");
    assert!(results.len() >= 9, "fixture set shrank to {}", results.len());
    for r in &results {
        assert!(
            r.pass(),
            "fixture {} expected {:?} but fired {:?}",
            r.fixture,
            r.expected,
            r.fired
        );
    }
    // every rule in the catalog has at least one covering fixture
    let all: Vec<&str> =
        results.iter().flat_map(|r| r.fired.iter().map(|s| s.as_str())).collect();
    for rule in ["D1", "D2", "D3", "U1", "W0", "R1"] {
        assert!(all.contains(&rule), "no fixture covers {rule}");
    }
}

#[test]
fn checked_in_tree_audits_clean() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    assert!(
        report.clean(),
        "audit findings on the checked-in tree:\n{}",
        report.render_text()
    );
    assert!(report.files > 40, "walk looks truncated: {} files", report.files);
    // the registry tiers were actually found and cross-checked
    assert!(
        !report.notes.iter().any(|n| n.contains("not found")),
        "R1 tier files missing: {:?}",
        report.notes
    );
}

#[test]
fn waiver_without_justification_is_rejected() {
    let f = AnalyzedFile::parse(
        "src/solver/x.rs",
        "// audit:allow(unordered-iter):\n\
         pub struct S { m: std::collections::HashMap<u32, u32> }\n",
    );
    let findings = analysis::check_file(&f);
    assert!(
        findings.iter().any(|fi| fi.rule == "D1"),
        "unjustified waiver must not suppress: {findings:?}"
    );
    assert!(findings.iter().any(|fi| fi.rule == "W0"), "{findings:?}");
}

#[test]
fn ratchet_increase_is_rejected() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    // take any nonzero counted metric and pretend its checked-in budget
    // was one lower — the recount must fail the ratchet
    let (key, &count) = report
        .counts
        .iter()
        .find(|(_, &v)| v > 0)
        .expect("some module has a panic site");
    let tightened = format!("[panic_budget]\n{key} = {}\n", count - 1);
    let r = Ratchet::parse(&tightened).expect("tightened ratchet parses");
    let (findings, _notes) = r.compare(&report.counts);
    assert!(
        findings.iter().any(|f| f.rule == "P1" && f.message.contains(key.as_str())),
        "{findings:?}"
    );
}
