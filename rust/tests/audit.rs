//! Auditor integration gate (DESIGN.md §10): the checked-in tree must
//! audit clean, the fixture self-check must fire exactly the expected
//! rules, the rejection paths (unjustified waiver, ratchet increase)
//! must stay closed, SARIF output must keep the 2.1.0 shape, and
//! differential mode must pass on an unchanged tree while flagging
//! planted findings as new.

use std::path::Path;

use dualip::analysis::{self, AnalyzedFile, AuditReport, Baseline, Finding, Ratchet};

fn root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn fixtures_fire_exactly_their_rules() {
    let results = analysis::self_check(root()).expect("fixtures present and well-formed");
    assert!(results.len() >= 12, "fixture set shrank to {}", results.len());
    for r in &results {
        assert!(
            r.pass(),
            "fixture {} expected {:?} but fired {:?}",
            r.fixture,
            r.expected,
            r.fired
        );
    }
    // every rule in the catalog has at least one covering fixture
    let all: Vec<&str> =
        results.iter().flat_map(|r| r.fired.iter().map(|s| s.as_str())).collect();
    for rule in ["D1", "D2", "D3", "U1", "W0", "R1", "P2", "D4", "A1"] {
        assert!(all.contains(&rule), "no fixture covers {rule}");
    }
}

#[test]
fn checked_in_tree_audits_clean() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    assert!(
        report.clean(),
        "audit findings on the checked-in tree:\n{}",
        report.render_text()
    );
    assert!(report.files > 40, "walk looks truncated: {} files", report.files);
    // the registry tiers were actually found and cross-checked
    assert!(
        !report.notes.iter().any(|n| n.contains("not found")),
        "R1 tier files missing: {:?}",
        report.notes
    );
}

#[test]
fn waiver_without_justification_is_rejected() {
    let f = AnalyzedFile::parse(
        "src/solver/x.rs",
        "// audit:allow(unordered-iter):\n\
         pub struct S { m: std::collections::HashMap<u32, u32> }\n",
    );
    let findings = analysis::check_file(&f);
    assert!(
        findings.iter().any(|fi| fi.rule == "D1"),
        "unjustified waiver must not suppress: {findings:?}"
    );
    assert!(findings.iter().any(|fi| fi.rule == "W0"), "{findings:?}");
}

#[test]
fn ratchet_increase_is_rejected() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    // take any nonzero counted panic metric and pretend its checked-in
    // budget was one lower — the recount must fail the ratchet
    let (key, &count) = report
        .counts
        .iter()
        .find(|(k, &v)| v > 0 && !k.ends_with(".alloc"))
        .expect("some module has a panic site");
    let tightened = format!("[panic_budget]\n{key} = {}\n", count - 1);
    let r = Ratchet::parse(&tightened).expect("tightened ratchet parses");
    let (findings, _notes) = r.compare(&report.counts);
    assert!(
        findings.iter().any(|f| f.rule == "P1" && f.message.contains(key.as_str())),
        "{findings:?}"
    );
}

#[test]
fn alloc_ratchet_increase_is_rejected_as_a1() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    // hot-loop alloc counts ride the same ratchet under `.alloc` keys;
    // an exceedance must come back as A1, not P1
    let Some((key, &count)) = report.counts.iter().find(|(k, &v)| v > 0 && k.ends_with(".alloc"))
    else {
        // a fully alloc-free cone is legal — nothing to tighten
        return;
    };
    let tightened = format!("[hot_loop_alloc]\n{key} = {}\n", count - 1);
    let r = Ratchet::parse(&tightened).expect("tightened ratchet parses");
    let (findings, _notes) = r.compare(&report.counts);
    assert!(
        findings.iter().any(|f| f.rule == "A1" && f.message.contains(key.as_str())),
        "{findings:?}"
    );
}

#[test]
fn sarif_output_has_the_2_1_0_shape() {
    // shape-check over a report that definitely carries findings, plus
    // the real tree's (possibly clean) report
    let mut probed = AuditReport::default();
    probed.findings.push(Finding::new(
        "analysis/ratchet.toml",
        0,
        "P1",
        "panic-budget",
        "tree-level finding".into(),
    ));
    probed.findings.push(Finding::new(
        "src/serve/daemon.rs",
        41,
        "P2",
        "panic-reachable",
        "chain here".into(),
    ));
    let real = analysis::audit_tree(root()).expect("audit runs").render_sarif();
    for s in [probed.render_sarif(), real] {
        for needle in [
            "\"$schema\": \"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json\"",
            "\"version\": \"2.1.0\"",
            "\"runs\": [",
            "\"tool\": {",
            "\"driver\": {",
            "\"name\": \"dualip-audit\"",
            "\"rules\": [",
            "\"results\": [",
        ] {
            assert!(s.contains(needle), "SARIF missing {needle}:\n{s}");
        }
        assert!(!s.contains("\"startLine\": 0"), "SARIF startLine must be >= 1");
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(s.matches(open).count(), s.matches(close).count(), "unbalanced SARIF");
        }
    }
}

#[test]
fn differential_passes_unchanged_tree_and_flags_planted_findings() {
    let report = analysis::audit_tree(root()).expect("audit runs");
    let base = Baseline::parse(&report.render_json()).expect("own JSON parses as baseline");
    assert!(
        base.new_findings(&report).is_empty(),
        "unchanged tree must have zero new findings vs its own baseline"
    );
    // a planted finding (what the CI injection probe produces) is new
    let mut probed = AuditReport::default();
    probed.findings.extend(report.findings.iter().cloned());
    probed.findings.push(Finding::new(
        "src/serve/probe.rs",
        9,
        "P2",
        "panic-reachable",
        "`.unwrap()` is reachable from a request entry point: \
         ServeDaemon::submit -> hop -> planted"
            .into(),
    ));
    let new = base.new_findings(&probed);
    assert_eq!(new.len(), 1, "{new:?}");
    assert_eq!(new[0].rule, "P2");
}
