//! Backend parity (seeded property harness, same style as proptests.rs):
//! the slab-native batched CPU objective must agree with the reference
//! tuple-layout objective — `calculate` and `primal` — to tight tolerance
//! on random instances across **every registered projection family**,
//! including split overwide separable rows and mixed-kind maps, and its
//! multithreaded evaluation must be bit-identical to 1 thread.

use dualip::backend::SlabCpuObjective;
use dualip::problem::{MatchingLp, ObjectiveFunction};
use dualip::projection::{registry, ProjectionKind, ProjectionMap};
use dualip::reference::CpuObjective;
use dualip::sparse::slabs::MAX_WIDTH;
use dualip::sparse::BlockedMatrix;
use dualip::util::rng::Rng;

/// Random matching LP with the given per-source degrees (distinct dests).
fn lp_with_degrees(
    rng: &mut Rng,
    degrees: &[usize],
    num_dests: usize,
    families: usize,
) -> MatchingLp {
    let mut src_ptr = vec![0usize];
    let mut dest_idx: Vec<u32> = Vec::new();
    for &deg in degrees {
        assert!(deg <= num_dests, "degree {deg} exceeds dest count {num_dests}");
        dest_idx.extend(rng.sample_distinct(num_dests, deg));
        src_ptr.push(dest_idx.len());
    }
    let nnz = dest_idx.len();
    let a: Vec<Vec<f32>> = (0..families)
        .map(|_| (0..nnz).map(|_| (rng.uniform() * 2.0 + 0.05) as f32).collect())
        .collect();
    let cost: Vec<f32> = (0..nnz).map(|_| -(rng.uniform() as f32) - 0.01).collect();
    let b: Vec<f32> = (0..families * num_dests)
        .map(|_| (rng.uniform() * 2.0 + 0.01) as f32)
        .collect();
    let m = BlockedMatrix {
        num_sources: degrees.len(),
        num_dests,
        num_families: families,
        src_ptr,
        dest_idx,
        a,
    };
    let lp = MatchingLp::new_uniform(m, cost, b, ProjectionKind::Simplex);
    lp.validate().unwrap();
    lp
}

fn random_lp(rng: &mut Rng, num_sources: usize, num_dests: usize, families: usize) -> MatchingLp {
    let deg_cap = 12.min(num_dests);
    let degrees: Vec<usize> = (0..num_sources).map(|_| rng.below(deg_cap + 1)).collect();
    lp_with_degrees(rng, &degrees, num_dests, families)
}

fn random_lam(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.uniform() * 0.3) as f32).collect()
}

/// Slab (1 thread) vs reference: calculate + primal within tight tolerance.
fn assert_parity(lp: &MatchingLp, lam: &[f32], gamma: f32, ctx: &str) {
    let mut slab = SlabCpuObjective::new(lp, 1)
        .unwrap_or_else(|e| panic!("{ctx}: slab layout must build, got error: {e}"));
    let mut reference = CpuObjective::new(lp);
    let rs = slab.calculate(lam, gamma);
    let rr = reference.calculate(lam, gamma);
    for (r, (a, b)) in rs.grad.iter().zip(&rr.grad).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "{ctx}: grad row {r}: slab {a} vs reference {b}"
        );
    }
    let close = |a: f64, b: f64, what: &str| {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "{ctx}: {what}: slab {a} vs reference {b}"
        );
    };
    close(rs.dual_obj, rr.dual_obj, "dual_obj");
    close(rs.cx, rr.cx, "cx");
    close(rs.xsq_weighted, rr.xsq_weighted, "xsq_weighted");
    close(rs.infeas_pos_norm, rr.infeas_pos_norm, "infeas_pos_norm");
    let xs = slab.primal(lam, gamma);
    let xr = reference.primal(lam, gamma);
    assert_eq!(xs.len(), xr.len(), "{ctx}: primal length");
    for (e, (a, b)) in xs.iter().zip(&xr).enumerate() {
        assert!((a - b).abs() <= 1e-4, "{ctx}: primal edge {e}: {a} vs {b}");
    }
}

/// Multithreaded slab evaluation is bit-identical to the 1-thread run.
fn assert_thread_invariant(lp: &MatchingLp, lam: &[f32], gamma: f32, ctx: &str) {
    let mut one = SlabCpuObjective::new(lp, 1).unwrap();
    let r1 = one.calculate(lam, gamma);
    let x1 = one.primal(lam, gamma);
    for threads in [2usize, 5, 8] {
        let mut many = SlabCpuObjective::new(lp, threads).unwrap();
        let rt = many.calculate(lam, gamma);
        assert_eq!(
            r1.dual_obj.to_bits(),
            rt.dual_obj.to_bits(),
            "{ctx}: dual_obj differs at {threads} threads"
        );
        assert_eq!(r1.cx.to_bits(), rt.cx.to_bits(), "{ctx}: cx at {threads} threads");
        assert_eq!(
            r1.xsq_weighted.to_bits(),
            rt.xsq_weighted.to_bits(),
            "{ctx}: xsq at {threads} threads"
        );
        for (r, (a, b)) in r1.grad.iter().zip(&rt.grad).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: grad row {r} differs at {threads} threads ({a} vs {b})"
            );
        }
        let xt = many.primal(lam, gamma);
        for (e, (a, b)) in x1.iter().zip(&xt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: primal edge {e} at {threads} threads");
        }
    }
}

#[test]
fn prop_slab_matches_reference_for_every_registered_family() {
    let mut rng = Rng::new(4242);
    for fam in registry::families() {
        for sample in registry::family_samples(&fam) {
            let kind = ProjectionKind::parse(&sample)
                .unwrap_or_else(|| panic!("sample {sample} must parse"));
            for case in 0..4 {
                let (ns, nd, nf) = (40 + rng.below(120), 8 + rng.below(24), 1 + rng.below(2));
                let mut lp = random_lp(&mut rng, ns, nd, nf);
                lp.projection = ProjectionMap::Uniform(kind);
                let lam = random_lam(&mut rng, lp.dual_dim());
                let gamma = if case % 2 == 0 { 0.05 } else { 0.3 };
                let ctx = format!("{sample} case {case}");
                assert_parity(&lp, &lam, gamma, &ctx);
                assert_thread_invariant(&lp, &lam, gamma, &ctx);
            }
        }
    }
}

#[test]
fn prop_split_overwide_separable_rows_match() {
    // box blocks wider than MAX_WIDTH are split across slab rows; the
    // box projection is separable so the reference (whole-block) result
    // must be recovered exactly through the split
    let mut rng = Rng::new(777);
    let num_dests = 2 * MAX_WIDTH + 32;
    for case in 0..3 {
        let degrees = vec![
            MAX_WIDTH + 30 + rng.below(20),
            3,
            2 * MAX_WIDTH + rng.below(16),
            0,
            1 + rng.below(8),
        ];
        let mut lp = lp_with_degrees(&mut rng, &degrees, num_dests, 1);
        lp.projection = ProjectionMap::Uniform(ProjectionKind::Box);
        let lam = random_lam(&mut rng, lp.dual_dim());
        let ctx = format!("overwide box case {case}");
        assert_parity(&lp, &lam, 0.1, &ctx);
        assert_thread_invariant(&lp, &lam, 0.1, &ctx);
    }
}

#[test]
fn prop_mixed_kind_maps_match() {
    let kinds = [
        ProjectionKind::Simplex,
        ProjectionKind::Box,
        ProjectionKind::capped_simplex(0.5, 1.0),
        ProjectionKind::parse("weighted_simplex:2:1,2").unwrap(),
        ProjectionKind::parse("box_vec:0.5,1.5").unwrap(),
    ];
    let mut rng = Rng::new(31337);
    for case in 0..5 {
        let (ns, nd) = (60 + rng.below(140), 10 + rng.below(20));
        let mut lp = random_lp(&mut rng, ns, nd, 1);
        lp.projection = ProjectionMap::per_block(move |i| kinds[i % kinds.len()]);
        let lam = random_lam(&mut rng, lp.dual_dim());
        let ctx = format!("mixed map case {case}");
        assert_parity(&lp, &lam, 0.2, &ctx);
        assert_thread_invariant(&lp, &lam, 0.2, &ctx);
    }
}

#[test]
fn prop_global_rows_and_primal_scale_match() {
    let mut rng = Rng::new(909);
    for case in 0..4 {
        let ns = 80 + rng.below(80);
        let mut lp = random_lp(&mut rng, ns, 12, 2);
        let nnz = lp.nnz();
        lp.push_global_row(vec![1.0; nnz], (rng.uniform() * 4.0 + 0.5) as f32);
        let coeffs: Vec<f32> = (0..nnz).map(|_| (rng.uniform() * 0.8) as f32).collect();
        lp.push_global_row(coeffs, (rng.uniform() * 2.0 + 0.1) as f32);
        lp.primal_scale = Some(
            (0..lp.num_sources()).map(|_| (rng.uniform() * 1.5 + 0.25) as f32).collect(),
        );
        lp.validate().unwrap();
        let lam = random_lam(&mut rng, lp.dual_dim());
        let ctx = format!("global+scale case {case}");
        assert_parity(&lp, &lam, 0.15, &ctx);
        assert_thread_invariant(&lp, &lam, 0.15, &ctx);
    }
}

#[test]
fn repeated_evaluations_are_pure_on_both_backends() {
    // scratch reuse (slab chunk buffers, reference ax buffer) must not
    // leak state across calls: same (λ, γ) twice → bitwise-same result,
    // with an unrelated evaluation in between
    let mut rng = Rng::new(55);
    let lp = random_lp(&mut rng, 150, 16, 1);
    let lam_a = random_lam(&mut rng, lp.dual_dim());
    let lam_b = random_lam(&mut rng, lp.dual_dim());

    let mut slab = SlabCpuObjective::new(&lp, 2).unwrap();
    let mut reference = CpuObjective::new(&lp);
    let s1 = slab.calculate(&lam_a, 0.1);
    let r1 = reference.calculate(&lam_a, 0.1);
    let _ = slab.calculate(&lam_b, 0.4);
    let _ = reference.calculate(&lam_b, 0.4);
    let s2 = slab.calculate(&lam_a, 0.1);
    let r2 = reference.calculate(&lam_a, 0.1);
    assert_eq!(s1.dual_obj.to_bits(), s2.dual_obj.to_bits());
    assert_eq!(r1.dual_obj.to_bits(), r2.dual_obj.to_bits());
    for ((a, b), (c, d)) in s1.grad.iter().zip(&s2.grad).zip(r1.grad.iter().zip(&r2.grad)) {
        assert_eq!(a.to_bits(), b.to_bits(), "slab not pure");
        assert_eq!(c.to_bits(), d.to_bits(), "reference not pure");
    }
}
