//! Experiment E7 — Lemma 5.1: under row normalization, the expected dual
//! Hessian E[ÃÃᵀ] has unit diagonal and, with cross-row correlation bound
//! η, condition number ≤ (1 + (m−1)η)/(1 − (m−1)η).
//!
//! We verify empirically on the matching-block model of Definition 1:
//! i.i.d. diagonal blocks per source, random per-family scales — and also
//! verify the *practical* statement on Appendix-B instances: Jacobi row
//! normalization collapses the spread of diag(AAᵀ) to exactly 1 and
//! shrinks the Gershgorin condition-number bound.

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::jacobi_row_normalize;
use dualip::util::rng::Rng;

/// Dense symmetric eigenvalue range via Jacobi rotations (small m only).
fn eig_range_sym(mut a: Vec<Vec<f64>>) -> (f64, f64) {
    let n = a.len();
    for _sweep in 0..100 {
        let mut off = 0.0;
        for p in 0..n {
            for q in p + 1..n {
                off += a[p][q] * a[p][q];
            }
        }
        if off < 1e-18 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                if a[p][q].abs() < 1e-15 {
                    continue;
                }
                let theta = 0.5 * (a[q][q] - a[p][p]) / a[p][q];
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let (akp, akq) = (a[k][p], a[k][q]);
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let (apk, aqk) = (a[p][k], a[q][k]);
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
            }
        }
    }
    let evs: Vec<f64> = (0..n).map(|i| a[i][i]).collect();
    (
        evs.iter().cloned().fold(f64::INFINITY, f64::min),
        evs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    )
}

/// Build E[ÃÃᵀ]-style sample: sum over I sources of normalized diagonal
/// blocks with m families over J dests, following Definition 1.
fn sample_aat(m: usize, j: usize, i_n: usize, seed: u64, corr: f64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    // row index = (k, jd) flattened k*j + jd over the mJ dual rows; AAᵀ is
    // block-diagonal over jd (diagonal blocks only couple same dest), so we
    // work per-dest on the m×m family Gram and average.
    let mut gram = vec![vec![0.0f64; m]; m];
    for _src in 0..i_n {
        for _jd in 0..j {
            // per-(source, dest) coefficient per family with shared factor
            // (controls cross-family correlation η)
            let shared = rng.lognormal(0.0, 1.0);
            let coeffs: Vec<f64> = (0..m)
                .map(|_| {
                    let own = rng.lognormal(0.0, 1.0);
                    corr * shared + (1.0 - corr) * own
                })
                .collect();
            for p in 0..m {
                for q in 0..m {
                    gram[p][q] += coeffs[p] * coeffs[q];
                }
            }
        }
    }
    // row-normalize: D = diag(gram)^{-1/2}
    let d: Vec<f64> = (0..m).map(|k| 1.0 / gram[k][k].sqrt()).collect();
    for p in 0..m {
        for q in 0..m {
            gram[p][q] *= d[p] * d[q];
        }
    }
    gram
}

#[test]
fn lemma51_unit_diagonal_after_normalization() {
    for m in [2usize, 4, 6] {
        let g = sample_aat(m, 8, 500, 3, 0.3);
        for k in 0..m {
            assert!((g[k][k] - 1.0).abs() < 1e-12, "diag {k} = {}", g[k][k]);
        }
    }
}

#[test]
fn lemma51_condition_number_bound() {
    // η = max off-diagonal of the normalized Gram; Gershgorin bound:
    // κ ≤ (1 + (m−1)η)/(1 − (m−1)η) whenever (m−1)η < 1.
    for (m, corr, seed) in [(2usize, 0.2, 1u64), (3, 0.3, 2), (4, 0.15, 3)] {
        let g = sample_aat(m, 8, 2000, seed, corr);
        let mut eta = 0.0f64;
        for p in 0..m {
            for q in 0..m {
                if p != q {
                    eta = eta.max(g[p][q].abs());
                }
            }
        }
        let slack = (m - 1) as f64 * eta;
        if slack >= 1.0 {
            continue; // bound vacuous for this draw
        }
        let (lo, hi) = eig_range_sym(g.clone());
        let kappa = hi / lo;
        let bound = (1.0 + slack) / (1.0 - slack);
        assert!(
            kappa <= bound + 1e-9,
            "m={m} corr={corr}: κ={kappa} > bound={bound} (η={eta})"
        );
    }
}

#[test]
fn jacobi_collapses_diag_spread_on_appendix_b_instance() {
    let mut lp = generate(&SyntheticConfig {
        num_requests: 5_000,
        num_resources: 100,
        avg_nnz_per_row: 10.0,
        num_families: 2,
        seed: 17,
        ..Default::default()
    });
    let before = lp.a.row_sq_norms();
    let nz: Vec<f64> = before.iter().cloned().filter(|&v| v > 0.0).collect();
    let spread_before = nz.iter().cloned().fold(0.0, f64::max)
        / nz.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        spread_before > 10.0,
        "Appendix-B rows should differ by orders of magnitude, got {spread_before}"
    );

    jacobi_row_normalize(&mut lp);
    for v in lp.a.row_sq_norms() {
        if v > 0.0 {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }
}

#[test]
fn normalization_shrinks_gershgorin_condition_bound() {
    // Practical corollary on a small dense-enough instance: compare the
    // Gershgorin-based κ bound of AAᵀ before and after normalization.
    let cfg = SyntheticConfig {
        num_requests: 400,
        num_resources: 20,
        avg_nnz_per_row: 8.0,
        seed: 5,
        ..Default::default()
    };
    let kappa_bound = |lp: &dualip::problem::MatchingLp| -> f64 {
        let csc = lp.a.to_csc();
        let aat = csc.aat_dense();
        let n = aat.len();
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for r in 0..n {
            if aat[r][r] == 0.0 {
                continue;
            }
            let off: f64 = (0..n).filter(|&c| c != r).map(|c| aat[r][c].abs()).sum();
            lo = lo.min((aat[r][r] - off).max(1e-9));
            hi = hi.max(aat[r][r] + off);
        }
        hi / lo
    };
    let mut lp = generate(&cfg);
    let before = kappa_bound(&lp);
    jacobi_row_normalize(&mut lp);
    let after = kappa_bound(&lp);
    assert!(
        after < before,
        "normalization should shrink the κ bound: {before} → {after}"
    );
}
