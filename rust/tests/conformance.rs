//! Generic projection-operator conformance suite, driven off the operator
//! registry: EVERY registered family is exercised through its registered
//! sample specs, with no per-family test code. A new constraint family
//! (trait impl + `register_family` with samples) gets this coverage for
//! free:
//!
//! - spec round-trip: `parse(spec(k)) == Some(k)` (interning identity);
//! - feasibility: `feasible(project(v))` per the operator's own oracle;
//! - idempotence: projecting a projected point is a no-op;
//! - non-expansiveness: ‖Π(u) − Π(v)‖ ≤ ‖u − v‖ (any convex projection);
//! - distance minimality on small blocks against a brute-force grid
//!   oracle over the positive orthant (all shipped polytopes live there).

use dualip::projection::{registry, BlockProjection, ProjectionKind};
use dualip::util::rng::Rng;

const CASES_PER_OP: usize = 60;
/// Grid oracle bounds: [0, GRID_MAX]^n in GRID_STEPS steps per axis.
/// Registered conformance samples must keep their polytopes inside this
/// box (bounds/totals ≲ 2.5), which all shipped samples do.
const GRID_MAX: f64 = 2.6;
const GRID_STEPS: usize = 13;

fn seed_of(label: &str) -> u64 {
    label.bytes().fold(0xC0F0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64))
}

fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum()
}

/// Enumerate the grid points of [0, GRID_MAX]^n (n ≤ 3 keeps this small).
fn grid_points(n: usize) -> Vec<Vec<f32>> {
    let axis: Vec<f32> = (0..=GRID_STEPS)
        .map(|s| (s as f64 * GRID_MAX / GRID_STEPS as f64) as f32)
        .collect();
    let mut pts: Vec<Vec<f32>> = vec![Vec::new()];
    for _ in 0..n {
        pts = pts
            .into_iter()
            .flat_map(|p| {
                axis.iter().map(move |&x| {
                    let mut q = p.clone();
                    q.push(x);
                    q
                })
            })
            .collect();
    }
    pts
}

fn conformance(k: ProjectionKind, label: &str) {
    let mut rng = Rng::new(seed_of(label));
    for case in 0..CASES_PER_OP {
        let n = 1 + rng.below(6);
        let scale = 10f64.powf(rng.uniform_range(-1.0, 1.0));
        let v: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();

        let mut p = v.clone();
        k.apply(&mut p);
        let tol = 1e-3 * scale.max(1.0);

        // feasibility via the operator's own oracle
        let viol = k.violation(&p);
        assert!(viol <= tol, "{label} case {case}: Π(v) infeasible by {viol}");

        // idempotence
        let mut p2 = p.clone();
        k.apply(&mut p2);
        for (a, b) in p.iter().zip(&p2) {
            assert!(
                ((a - b).abs() as f64) <= tol,
                "{label} case {case}: not idempotent ({a} vs {b})"
            );
        }

        // non-expansiveness against a second random point
        let u: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        let mut pu = u.clone();
        k.apply(&mut pu);
        let d_in = dist_sq(&u, &v);
        let d_out = dist_sq(&pu, &p);
        assert!(
            d_out <= d_in + tol,
            "{label} case {case}: expansive ({d_out} > {d_in})"
        );

        // distance minimality vs the brute-force grid oracle
        if n <= 3 {
            let d_star = dist_sq(&v, &p);
            for g in grid_points(n) {
                if k.feasible(&g, 1e-9) {
                    let d = dist_sq(&v, &g);
                    assert!(
                        d_star <= d + tol,
                        "{label} case {case}: grid point {g:?} beat Π(v) \
                         ({d} < {d_star})"
                    );
                }
            }
        }
    }
}

/// Run the generic suite over everything currently registered.
fn conformance_over_registry() {
    for fam in registry::families() {
        let samples = registry::family_samples(&fam);
        assert!(!samples.is_empty(), "family {fam} registered without samples");
        for spec in samples {
            let k = ProjectionKind::parse(&spec)
                .unwrap_or_else(|| panic!("sample {spec} of family {fam} must parse"));
            assert_eq!(k.name(), fam, "sample {spec} resolved outside its family");
            assert_eq!(
                ProjectionKind::parse(&k.spec()),
                Some(k),
                "canonical spec of {spec} must round-trip"
            );
            conformance(k, &spec);
        }
    }
}

#[test]
fn every_registered_family_passes_conformance() {
    let families = registry::families();
    for required in ["simplex", "box", "capped_simplex", "weighted_simplex", "box_vec"] {
        assert!(
            families.contains(&required.to_string()),
            "builtin family {required} missing from registry: {families:?}"
        );
    }
    conformance_over_registry();
}

#[test]
fn runtime_registered_family_is_covered_for_free() {
    // The extension path: a downstream crate registers a family and the
    // same generic suite covers it with zero new test code. Scaled box
    // [0, s]^n with spec `scaled_box_test:<s>`.
    struct ScaledBox {
        s: f32,
    }
    impl BlockProjection for ScaledBox {
        fn family(&self) -> &str {
            "scaled_box_test"
        }
        fn spec(&self) -> String {
            format!("scaled_box_test:{}", self.s)
        }
        fn project(&self, v: &mut [f32]) {
            for x in v.iter_mut() {
                *x = x.clamp(0.0, self.s);
            }
        }
        fn violation(&self, v: &[f32]) -> f64 {
            v.iter()
                .map(|&x| ((x - self.s) as f64).max((-x) as f64).max(0.0))
                .fold(0.0, f64::max)
        }
        fn separable(&self) -> bool {
            true
        }
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
    }
    registry::register_family(
        "scaled_box_test",
        &["scaled_box_test:0.75", "scaled_box_test:2"],
        |args: &str| {
            let s: f32 = if args.is_empty() { 1.0 } else { args.parse().ok()? };
            (s > 0.0 && s.is_finite())
                .then(|| Box::new(ScaledBox { s }) as Box<dyn BlockProjection>)
        },
    );
    assert!(registry::families().contains(&"scaled_box_test".to_string()));
    conformance_over_registry();
}
