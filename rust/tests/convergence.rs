//! Experiment E8 (Lemma A.1) + end-to-end convergence quality of the full
//! solver stack on Appendix-B instances.

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{check_primal, jacobi_row_normalize, ObjectiveFunction};
use dualip::reference::CpuObjective;
use dualip::solver::{Agd, GammaSchedule, Maximizer, Pgd, SolveOptions};

fn instance(seed: u64) -> dualip::problem::MatchingLp {
    generate(&SyntheticConfig {
        num_requests: 3_000,
        num_resources: 120,
        avg_nnz_per_row: 8.0,
        seed,
        ..Default::default()
    })
}

#[test]
fn lemma_a1_infeasibility_bound_holds_along_trajectory() {
    let lp = instance(1);
    let gamma = 0.05f32;
    let mut obj = CpuObjective::new(&lp);
    let mut agd = Agd::default();
    let opts = SolveOptions {
        max_iters: 400,
        gamma: GammaSchedule::Fixed(gamma),
        max_step_size: 1e-2,
        initial_step_size: 1e-5,
        ..Default::default()
    };
    let r = agd.maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);

    // g(λ*) estimated by the best value seen on a longer run
    let opts_long = SolveOptions { max_iters: 1500, ..opts.clone() };
    let mut obj2 = CpuObjective::new(&lp);
    let r_long = Agd::default().maximize(&mut obj2, &vec![0.0; lp.dual_dim()], &opts_long);
    let g_star = r_long
        .trajectory
        .iter()
        .map(|t| t.dual_obj)
        .fold(f64::NEG_INFINITY, f64::max);

    // L = ‖A‖²/γ (Holder upper bound on ‖A‖²)
    let l_const = lp.a.op_norm_sq_upper() / gamma as f64;
    let mut checked = 0;
    for t in &r.trajectory {
        let gap = (g_star - t.dual_obj).max(0.0);
        let bound = (2.0 * l_const * gap).sqrt();
        assert!(
            t.infeas_pos_norm <= bound + 1e-6,
            "iter {}: ‖(Ax−b)₊‖ = {} > bound {}",
            t.iter,
            t.infeas_pos_norm,
            bound
        );
        checked += 1;
    }
    assert!(checked >= 400);
}

#[test]
fn infeasibility_decreases_with_dual_convergence() {
    // Run the paper's own pipeline: Jacobi conditioning first (an
    // unconditioned Appendix-B instance has ‖A‖ spanning orders of
    // magnitude, so a capped-step run sits far from convergence; §5.1).
    let mut lp = instance(2);
    jacobi_row_normalize(&mut lp);
    let mut obj = CpuObjective::new(&lp);
    let opts = SolveOptions {
        max_iters: 600,
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1.0,
        ..Default::default()
    };
    let r = Agd::default().maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);
    let early = r.trajectory[10].infeas_pos_norm;
    let late = r.trajectory.last().unwrap().infeas_pos_norm;
    assert!(
        late < early * 0.2,
        "infeasibility should shrink substantially: {early} → {late}"
    );
}

#[test]
fn continuation_reaches_floor_and_improves_over_large_fixed_gamma() {
    let mut lp = instance(3);
    jacobi_row_normalize(&mut lp);
    let base = SolveOptions {
        max_iters: 300,
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };

    let run = |sched: GammaSchedule| {
        let mut obj = CpuObjective::new(&lp);
        let opts = SolveOptions { gamma: sched, ..base.clone() };
        Agd::default().maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts)
    };
    let r_decay = run(GammaSchedule::paper_fig5());
    let r_big = run(GammaSchedule::Fixed(0.16));

    assert_eq!(r_decay.final_gamma, 0.01);
    // the decayed run's λ must be a better dual point for the γ-floor
    // problem (g(λ) is a valid lower bound there — higher is better)
    let mut obj = CpuObjective::new(&lp);
    let g_decay = obj.calculate(&r_decay.lam, 0.01).dual_obj;
    let g_big = obj.calculate(&r_big.lam, 0.01).dual_obj;
    assert!(
        g_decay >= g_big - 1e-6,
        "continuation should reach a better γ-floor dual: {g_decay} vs {g_big}"
    );
}

#[test]
fn preconditioned_solve_converges_faster_per_iteration() {
    // Fig-4 statement as a test: at matched iteration budget, the Jacobi
    // run attains a higher dual objective (on the same underlying LP; dual
    // values are comparable because row scaling preserves the perturbed
    // primal optimum).
    let lp_raw = instance(4);
    let mut lp_pre = instance(4);
    jacobi_row_normalize(&mut lp_pre);

    let run = |lp: &dualip::problem::MatchingLp, cap: f64, iters: usize| {
        let mut obj = CpuObjective::new(lp);
        let opts = SolveOptions {
            max_iters: iters,
            gamma: GammaSchedule::Fixed(0.01),
            max_step_size: cap,
            ..Default::default()
        };
        Agd::default().maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts)
    };
    // long runs agree on the optimum value (sanity: scaling preserves it)
    let g_raw_long = run(&lp_raw, 1e-3, 4000).final_obj.dual_obj;
    let g_pre_long = run(&lp_pre, 1.0, 800).final_obj.dual_obj;
    assert!(
        (g_raw_long - g_pre_long).abs() / g_raw_long.abs() < 2e-2,
        "optima should agree: {g_raw_long} vs {g_pre_long}"
    );

    // short runs: preconditioned gets much closer to the optimum
    let g_star = g_pre_long.max(g_raw_long);
    let gap_raw = (g_star - run(&lp_raw, 1e-3, 150).final_obj.dual_obj).abs();
    let gap_pre = (g_star - run(&lp_pre, 1.0, 150).final_obj.dual_obj).abs();
    assert!(
        gap_pre < gap_raw * 0.5,
        "preconditioning should at least halve the 150-iter gap: raw {gap_raw} pre {gap_pre}"
    );
}

#[test]
fn agd_dominates_pgd_on_matching_instance() {
    let lp = instance(5);
    let opts = SolveOptions {
        max_iters: 200,
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1e-2,
        ..Default::default()
    };
    let mut o1 = CpuObjective::new(&lp);
    let ra = Agd::default().maximize(&mut o1, &vec![0.0; lp.dual_dim()], &opts);
    let mut o2 = CpuObjective::new(&lp);
    let rp = Pgd.maximize(&mut o2, &vec![0.0; lp.dual_dim()], &opts);
    assert!(
        ra.final_obj.dual_obj >= rp.final_obj.dual_obj - 1e-6,
        "AGD {} vs PGD {}",
        ra.final_obj.dual_obj,
        rp.final_obj.dual_obj
    );
}

#[test]
fn rounded_primal_is_feasible_and_near_dual_bound() {
    // Solve (conditioned, per §5.1), recover x*γ(λ), validate end to end.
    let mut lp = instance(6);
    jacobi_row_normalize(&mut lp);
    let mut obj = CpuObjective::new(&lp);
    let opts = SolveOptions {
        max_iters: 800,
        gamma: GammaSchedule::paper_fig5(),
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let r = Agd::default().maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);
    let x = obj.primal(&r.lam, r.final_gamma);
    let rep = check_primal(&lp, &x, 1e-3);
    // simple constraints hold by construction (projection)
    assert!(rep.simple_infeas_max < 1e-5, "{}", rep.simple_infeas_max);
    // complex infeasibility small relative to objective scale
    assert!(
        rep.complex_infeas < 0.02 * rep.objective.abs(),
        "‖(Ax−b)₊‖ {} vs obj {}",
        rep.complex_infeas,
        rep.objective
    );
    // weak duality: g ≤ cᵀx + γ/2‖x‖² at the final γ
    let res = obj.calculate(&r.lam, r.final_gamma);
    assert!(res.dual_obj <= rep.objective + 0.5 * r.final_gamma as f64 * res.xsq_weighted + 1e-3);
}
