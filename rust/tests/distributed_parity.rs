//! Sharded-slab parity (seeded property harness, same style as
//! `proptests.rs` / `backend_parity.rs`): an S-shard slab solve must be
//! **bit-identical** to the single-shard slab solve — per evaluation and
//! over whole AGD trajectories — for S ∈ {2, 3, 4}, across every
//! registered projection family, including split overwide separable rows
//! and global rows, on both sharded execution paths (the in-process
//! `ShardedSlabObjective` and the `WorkerPool` device-thread pool, which
//! needs no artifacts under the slab strategy).

use std::sync::Arc;

use dualip::backend::{ShardedSlabObjective, SlabCpuObjective};
use dualip::distributed::{solve_distributed_with, ExecStrategy};
use dualip::problem::{MatchingLp, ObjectiveFunction};
use dualip::projection::{registry, ProjectionKind, ProjectionMap};
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};
use dualip::sparse::slabs::MAX_WIDTH;
use dualip::sparse::BlockedMatrix;
use dualip::util::rng::Rng;

/// Random matching LP with the given per-source degrees (distinct dests).
fn lp_with_degrees(
    rng: &mut Rng,
    degrees: &[usize],
    num_dests: usize,
    families: usize,
) -> MatchingLp {
    let mut src_ptr = vec![0usize];
    let mut dest_idx: Vec<u32> = Vec::new();
    for &deg in degrees {
        assert!(deg <= num_dests, "degree {deg} exceeds dest count {num_dests}");
        dest_idx.extend(rng.sample_distinct(num_dests, deg));
        src_ptr.push(dest_idx.len());
    }
    let nnz = dest_idx.len();
    let a: Vec<Vec<f32>> = (0..families)
        .map(|_| (0..nnz).map(|_| (rng.uniform() * 2.0 + 0.05) as f32).collect())
        .collect();
    let cost: Vec<f32> = (0..nnz).map(|_| -(rng.uniform() as f32) - 0.01).collect();
    let b: Vec<f32> = (0..families * num_dests)
        .map(|_| (rng.uniform() * 2.0 + 0.01) as f32)
        .collect();
    let m = BlockedMatrix {
        num_sources: degrees.len(),
        num_dests,
        num_families: families,
        src_ptr,
        dest_idx,
        a,
    };
    let lp = MatchingLp::new_uniform(m, cost, b, ProjectionKind::Simplex);
    lp.validate().unwrap();
    lp
}

fn random_lp(rng: &mut Rng, num_sources: usize, num_dests: usize, families: usize) -> MatchingLp {
    let deg_cap = 12.min(num_dests);
    let degrees: Vec<usize> = (0..num_sources).map(|_| rng.below(deg_cap + 1)).collect();
    lp_with_degrees(rng, &degrees, num_dests, families)
}

fn random_lam(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.uniform() * 0.3) as f32).collect()
}

/// One sharded evaluation (calculate + primal) vs the single-shard slab
/// objective — bit equality of every output.
fn assert_shard_bitwise(lp: &MatchingLp, lam: &[f32], gamma: f32, ctx: &str) {
    let mut one = SlabCpuObjective::new(lp, 1)
        .unwrap_or_else(|e| panic!("{ctx}: slab layout must build, got error: {e}"));
    let r1 = one.calculate(lam, gamma);
    let x1 = one.primal(lam, gamma);
    for shards in [2usize, 3, 4] {
        let mut sh = ShardedSlabObjective::new(lp, shards, 1).unwrap();
        let rs = sh.calculate(lam, gamma);
        assert_eq!(
            r1.dual_obj.to_bits(),
            rs.dual_obj.to_bits(),
            "{ctx}: dual_obj differs at {shards} shards"
        );
        assert_eq!(r1.cx.to_bits(), rs.cx.to_bits(), "{ctx}: cx at {shards} shards");
        assert_eq!(
            r1.xsq_weighted.to_bits(),
            rs.xsq_weighted.to_bits(),
            "{ctx}: xsq at {shards} shards"
        );
        for (r, (a, b)) in r1.grad.iter().zip(&rs.grad).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: grad row {r} differs at {shards} shards ({a} vs {b})"
            );
        }
        let xs = sh.primal(lam, gamma);
        for (e, (a, b)) in x1.iter().zip(&xs).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: primal edge {e} at {shards} shards");
        }
    }
}

#[test]
fn prop_sharded_eval_bitwise_for_every_registered_family() {
    let mut rng = Rng::new(20260726);
    for fam in registry::families() {
        for sample in registry::family_samples(&fam) {
            let kind = ProjectionKind::parse(&sample)
                .unwrap_or_else(|| panic!("sample {sample} must parse"));
            for case in 0..3 {
                let (ns, nd, nf) = (60 + rng.below(160), 8 + rng.below(24), 1 + rng.below(2));
                let mut lp = random_lp(&mut rng, ns, nd, nf);
                lp.projection = ProjectionMap::Uniform(kind);
                let lam = random_lam(&mut rng, lp.dual_dim());
                let gamma = if case % 2 == 0 { 0.05 } else { 0.3 };
                assert_shard_bitwise(&lp, &lam, gamma, &format!("{sample} case {case}"));
            }
        }
    }
}

#[test]
fn prop_sharded_eval_bitwise_with_overwide_separable_rows() {
    // box blocks wider than MAX_WIDTH split across slab rows (and the
    // split rows land in MAX_WIDTH-width chunks that the shard partition
    // is free to separate) — the sharded merge must still reproduce the
    // single-shard bits exactly
    let mut rng = Rng::new(424243);
    let num_dests = 2 * MAX_WIDTH + 32;
    for case in 0..3 {
        let mut degrees = vec![
            MAX_WIDTH + 30 + rng.below(20),
            2 * MAX_WIDTH + rng.below(16),
        ];
        degrees.extend((0..40).map(|_| 1 + rng.below(10)));
        let mut lp = lp_with_degrees(&mut rng, &degrees, num_dests, 1);
        lp.projection = ProjectionMap::Uniform(ProjectionKind::Box);
        let lam = random_lam(&mut rng, lp.dual_dim());
        assert_shard_bitwise(&lp, &lam, 0.1, &format!("overwide box case {case}"));
    }
}

#[test]
fn prop_sharded_eval_bitwise_with_global_rows_and_mixed_kinds() {
    let kinds = [
        ProjectionKind::Simplex,
        ProjectionKind::Box,
        ProjectionKind::capped_simplex(0.5, 1.0),
    ];
    let mut rng = Rng::new(777001);
    for case in 0..3 {
        let ns = 80 + rng.below(120);
        let mut lp = random_lp(&mut rng, ns, 14, 2);
        lp.projection = ProjectionMap::per_block(move |i| kinds[i % kinds.len()]);
        let nnz = lp.nnz();
        lp.push_global_row(vec![1.0; nnz], (rng.uniform() * 4.0 + 0.5) as f32);
        let coeffs: Vec<f32> = (0..nnz).map(|_| (rng.uniform() * 0.8) as f32).collect();
        lp.push_global_row(coeffs, (rng.uniform() * 2.0 + 0.1) as f32);
        lp.validate().unwrap();
        let lam = random_lam(&mut rng, lp.dual_dim());
        assert_shard_bitwise(&lp, &lam, 0.15, &format!("global rows case {case}"));
    }
}

#[test]
fn prop_whole_solves_bitwise_identical_across_shard_counts() {
    // whole AGD trajectories, not just single evaluations: the adaptive
    // step-size search amplifies any stray bit into divergent iterates,
    // so λ equality after a real solve is the end-to-end contract
    let mut rng = Rng::new(9090);
    let opts = SolveOptions {
        max_iters: 40,
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1e-2,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    for case in 0..2 {
        let lp = random_lp(&mut rng, 200 + rng.below(200), 20, 1);
        let mut one = SlabCpuObjective::new(&lp, 1).unwrap();
        let mut agd = Agd::default();
        let r1 = agd.maximize(&mut one, &vec![0.0; lp.dual_dim()], &opts);
        for shards in [2usize, 3, 4] {
            let mut sh = ShardedSlabObjective::new(&lp, shards, 1).unwrap();
            let mut agd_s = Agd::default();
            let rs = agd_s.maximize(&mut sh, &vec![0.0; lp.dual_dim()], &opts);
            assert_eq!(r1.iterations, rs.iterations, "case {case}, {shards} shards");
            for (i, (a, b)) in r1.lam.iter().zip(&rs.lam).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case}: λ[{i}] differs at {shards} shards"
                );
            }
            assert_eq!(
                r1.trajectory.last().unwrap().dual_obj.to_bits(),
                rs.trajectory.last().unwrap().dual_obj.to_bits()
            );
        }
    }
}

#[test]
fn worker_pool_slab_strategy_matches_in_process_sharding_bitwise() {
    // the device-thread path (persistent workers + channels) and the
    // in-process path must agree with each other and with single-shard —
    // all three are the same chunk grid merged in the same order
    let mut rng = Rng::new(31415);
    let lp = Arc::new(random_lp(&mut rng, 350, 24, 2));
    let opts = SolveOptions {
        max_iters: 30,
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1e-2,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let mut one = SlabCpuObjective::new(&lp, 1).unwrap();
    let mut agd = Agd::default();
    let r1 = agd.maximize(&mut one, &vec![0.0; lp.dual_dim()], &opts);
    for shards in [2usize, 3] {
        let pool = solve_distributed_with(
            lp.clone(),
            ExecStrategy::Slab { threads: 1 },
            shards,
            &opts,
        )
        .unwrap();
        let mut inproc = ShardedSlabObjective::new(&lp, shards, 1).unwrap();
        let mut agd_i = Agd::default();
        let ri = agd_i.maximize(&mut inproc, &vec![0.0; lp.dual_dim()], &opts);
        for ((a, b), c) in r1.lam.iter().zip(&pool.result.lam).zip(&ri.lam) {
            assert_eq!(a.to_bits(), b.to_bits(), "pool path diverged at {shards} shards");
            assert_eq!(a.to_bits(), c.to_bits(), "in-process path diverged at {shards} shards");
        }
    }
}

#[test]
fn per_shard_thread_width_never_changes_bits() {
    let mut rng = Rng::new(5150);
    let lp = random_lp(&mut rng, 400, 20, 1);
    let lam = random_lam(&mut rng, lp.dual_dim());
    let mut base = ShardedSlabObjective::new(&lp, 3, 1).unwrap();
    let r0 = base.calculate(&lam, 0.1);
    for threads in [2usize, 5] {
        let mut wide = ShardedSlabObjective::new(&lp, 3, threads).unwrap();
        let rt = wide.calculate(&lam, 0.1);
        assert_eq!(r0.dual_obj.to_bits(), rt.dual_obj.to_bits());
        for (a, b) in r0.grad.iter().zip(&rt.grad) {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads/shard changed bits");
        }
    }
}
