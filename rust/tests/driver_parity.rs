//! Driver parity — the api_redesign acceptance suite.
//!
//! The steppable `SolveDriver` replaced the private run-to-completion
//! loop, with `Maximizer::maximize` now a thin wrapper over it. These
//! tests pin the contract:
//!
//! - manually stepping the driver is **bit-identical** (λ, trajectory,
//!   stop reason, iteration count) to `maximize()` for both optimizers
//!   (AGD, PGD), across EVERY registered projection family's conformance
//!   samples, warm- and cold-started;
//! - checkpoint at iteration k + resume ≡ an uninterrupted run;
//! - a 16-job cooperative batch with per-job deadlines is deterministic
//!   across pool widths, deadline-stopped jobs report
//!   `StopReason::Deadline`, and their published anytime duals warm
//!   subsequent solves.

use dualip::backend::CpuBackend;
use dualip::engine::{EngineConfig, SolveEngine, SolveJob};
use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{jacobi_row_normalize, MatchingLp, ObjectiveFunction};
use dualip::projection::{registry, ProjectionKind, ProjectionMap};
use dualip::solver::{
    Agd, DriverOptions, DualStepper, GammaSchedule, Maximizer, Pgd, SolveDriver, SolveOptions,
    SolveResult, StepEvent, StopReason, StoppingCriteria,
};

/// Small conditioned instance with the given blockwise polytope.
fn family_lp(kind: ProjectionKind, seed: u64) -> MatchingLp {
    let mut lp = generate(&SyntheticConfig {
        num_requests: 240,
        num_resources: 24,
        avg_nnz_per_row: 5.0,
        seed,
        ..Default::default()
    });
    lp.projection = ProjectionMap::Uniform(kind);
    jacobi_row_normalize(&mut lp);
    lp
}

/// Mixed continuation + stall options exercising γ decay, the record
/// cadence (≠ 1, so the stopping-iteration fix matters), and early stops.
fn parity_options() -> SolveOptions {
    SolveOptions {
        max_iters: 400,
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 10 },
        stopping: StoppingCriteria {
            stall_tol: Some(1e-6),
            stall_patience: 8,
            min_iters: 21, // past the γ descent
            ..Default::default()
        },
        record_every: 3,
    }
}

fn objective(lp: &MatchingLp) -> impl ObjectiveFunction + '_ {
    CpuBackend::Slab.objective(lp, 1)
}

fn assert_bit_identical(a: &SolveResult, b: &SolveResult, ctx: &str) {
    assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
    assert_eq!(a.stop_reason, b.stop_reason, "{ctx}: stop reason");
    assert_eq!(a.final_gamma.to_bits(), b.final_gamma.to_bits(), "{ctx}: final γ");
    assert_eq!(a.lam.len(), b.lam.len(), "{ctx}: λ length");
    for (i, (x, y)) in a.lam.iter().zip(&b.lam).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: λ[{i}]");
    }
    assert_eq!(a.final_obj.dual_obj.to_bits(), b.final_obj.dual_obj.to_bits(), "{ctx}: g");
    assert_eq!(a.trajectory.len(), b.trajectory.len(), "{ctx}: trajectory length");
    for (ta, tb) in a.trajectory.iter().zip(&b.trajectory) {
        assert_eq!(ta.iter, tb.iter, "{ctx}: record iter");
        assert_eq!(ta.dual_obj.to_bits(), tb.dual_obj.to_bits(), "{ctx}: record g");
        assert_eq!(ta.grad_norm.to_bits(), tb.grad_norm.to_bits(), "{ctx}: record ‖∇g‖");
        assert_eq!(ta.step_size.to_bits(), tb.step_size.to_bits(), "{ctx}: record η");
        assert_eq!(ta.gamma.to_bits(), tb.gamma.to_bits(), "{ctx}: record γ");
    }
}

/// Manual `step()` loop vs one-shot `maximize()` on fresh objectives.
fn assert_stepping_matches_maximize(
    lp: &MatchingLp,
    init: &[f32],
    opts: &SolveOptions,
    legacy: &SolveResult,
    stepper: Box<dyn DualStepper>,
    ctx: &str,
) {
    let mut obj = objective(lp);
    let mut driver = SolveDriver::new(stepper, init, opts.clone(), DriverOptions::default());
    loop {
        match driver.step(&mut obj) {
            StepEvent::Stopped { .. } => break,
            StepEvent::Continue { .. } | StepEvent::GammaDecayed { .. } => {}
        }
    }
    let stepped = driver.result(&mut obj);
    assert_bit_identical(legacy, &stepped, ctx);
}

/// Checkpoint at iteration k, resume, finish: must equal the straight run.
fn assert_resume_matches_straight(
    lp: &MatchingLp,
    init: &[f32],
    opts: &SolveOptions,
    legacy: &SolveResult,
    k: usize,
    ctx: &str,
) {
    let mut obj = objective(lp);
    let mut d = SolveDriver::new(
        Box::new(Agd::default().stepper()),
        init,
        opts.clone(),
        DriverOptions::default(),
    );
    for _ in 0..k {
        if let StepEvent::Stopped { .. } = d.step(&mut obj) {
            break;
        }
    }
    let ck = d.checkpoint().expect("AGD steppers are checkpointable");
    drop(d);
    let mut resumed = SolveDriver::resume(ck);
    let r = resumed.run(&mut obj);
    assert_bit_identical(legacy, &r, &format!("{ctx} (resume at {k})"));
}

#[test]
fn driver_stepping_is_bit_identical_for_every_registered_family() {
    let opts = parity_options();
    for (f, fam) in registry::families().into_iter().enumerate() {
        for (s, sample) in registry::family_samples(&fam).into_iter().enumerate() {
            let kind = ProjectionKind::parse(&sample)
                .unwrap_or_else(|| panic!("sample {sample} must parse"));
            let lp = family_lp(kind, 100 + (f * 10 + s) as u64);
            let cold_init = vec![0.0f32; lp.dual_dim()];

            // --- AGD, cold ------------------------------------------------
            let mut agd = Agd::default();
            let cold = agd.maximize(&mut objective(&lp), &cold_init, &opts);
            assert!(
                cold.iterations > 0 && cold.iterations <= opts.max_iters,
                "{sample}: degenerate cold solve"
            );
            assert_stepping_matches_maximize(
                &lp,
                &cold_init,
                &opts,
                &cold,
                Box::new(Agd::default().stepper()),
                &format!("{sample}/agd/cold"),
            );

            // --- AGD, warm (restart from the cold λ, engine-style tail) ---
            let warm_opts = dualip::engine::warm_options(&opts, 4);
            let warm = agd.maximize(&mut objective(&lp), &cold.lam, &warm_opts);
            assert_stepping_matches_maximize(
                &lp,
                &cold.lam,
                &warm_opts,
                &warm,
                Box::new(Agd::default().stepper()),
                &format!("{sample}/agd/warm"),
            );

            // --- PGD, cold + warm ----------------------------------------
            let mut pgd = Pgd;
            let pcold = pgd.maximize(&mut objective(&lp), &cold_init, &opts);
            assert_stepping_matches_maximize(
                &lp,
                &cold_init,
                &opts,
                &pcold,
                Box::new(Pgd.stepper()),
                &format!("{sample}/pgd/cold"),
            );
            let pwarm = pgd.maximize(&mut objective(&lp), &pcold.lam, &warm_opts);
            assert_stepping_matches_maximize(
                &lp,
                &pcold.lam,
                &warm_opts,
                &pwarm,
                Box::new(Pgd.stepper()),
                &format!("{sample}/pgd/warm"),
            );

            // --- checkpoint/resume mid-schedule (first sample per family,
            // paused inside the γ descent) --------------------------------
            if s == 0 {
                assert_resume_matches_straight(&lp, &cold_init, &opts, &cold, 17, &fam);
            }
        }
    }
}

#[test]
fn stopping_iteration_is_recorded_even_off_cadence() {
    // satellite: an early stall stop at t % record_every != 0 used to drop
    // the final record — the trajectory ended before final_obj
    let lp = family_lp(ProjectionKind::Simplex, 7);
    let opts = SolveOptions { record_every: 50, ..parity_options() };
    let r = Agd::default().maximize(&mut objective(&lp), &vec![0.0; lp.dual_dim()], &opts);
    let last = r.trajectory.last().expect("non-empty trajectory");
    assert_eq!(last.iter, r.iterations - 1, "stopping iteration must be recorded");
    assert_eq!(last.dual_obj.to_bits(), r.final_obj.dual_obj.to_bits());
    // and off-cadence stops are not double-recorded on cadence hits
    let iters: Vec<usize> = r.trajectory.iter().map(|t| t.iter).collect();
    let mut dedup = iters.clone();
    dedup.dedup();
    assert_eq!(iters, dedup, "no duplicate records");
}

#[test]
fn zero_budget_solve_reports_a_real_evaluation() {
    // satellite: max_iters == 0 used to leak dual_obj = −∞ into engine
    // stats and BENCH JSON
    let lp = family_lp(ProjectionKind::Simplex, 9);
    let opts = SolveOptions { max_iters: 0, ..parity_options() };
    let r = Agd::default().maximize(&mut objective(&lp), &vec![0.0; lp.dual_dim()], &opts);
    assert_eq!(r.iterations, 0);
    assert_eq!(r.stop_reason, StopReason::MaxIters);
    assert!(r.trajectory.is_empty());
    assert!(r.final_obj.dual_obj.is_finite(), "evaluation-at-init, not −∞");
    assert_eq!(r.final_obj.grad.len(), lp.dual_dim());

    // and through the engine: no −∞ in JobResult either
    let engine = SolveEngine::new(EngineConfig {
        opts,
        cache_capacity: 4,
        threads: 1,
        ..Default::default()
    });
    let jr = engine.submit(SolveJob::new(0, lp));
    assert!(jr.dual_obj.is_finite());
}

fn coop_cfg(threads: usize) -> EngineConfig {
    EngineConfig {
        opts: SolveOptions {
            max_iters: 600,
            max_step_size: 1.0,
            initial_step_size: 1e-4,
            gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 8 },
            stopping: StoppingCriteria {
                stall_tol: Some(1e-6),
                stall_patience: 8,
                ..Default::default()
            },
            record_every: 100,
        },
        warm_tail: 4,
        threads,
        cache_capacity: 16,
        backend: CpuBackend::Slab,
        objective_threads: 1,
        shards: 1,
        deadline_ms: None,
        quantum: 5,
    }
}

/// 16 jobs over 4 distinct patterns; every 4th job carries a zero
/// deadline (deterministic: exactly one iteration, then Deadline).
fn coop_jobs() -> Vec<SolveJob> {
    (0..16u64)
        .map(|k| {
            let job = SolveJob::new(k, family_lp(ProjectionKind::Simplex, 200 + k % 4));
            if k % 4 == 3 {
                job.with_deadline_ms(0.0)
            } else {
                job
            }
        })
        .collect()
}

#[test]
fn coop_16_job_deadline_batch_is_deterministic_across_pool_widths() {
    let run = |threads: usize| {
        let engine = SolveEngine::new(coop_cfg(threads));
        let (results, report) = engine.solve_batch_coop(coop_jobs());
        (results, report, engine)
    };
    let (base, base_report, base_engine) = run(1);
    assert_eq!(base.len(), 16);
    assert_eq!(base_report.deadline_stops, 4);
    for r in &base {
        if r.id % 4 == 3 {
            assert_eq!(r.stop_reason, StopReason::Deadline, "job {}", r.id);
            assert_eq!(r.iterations, 1, "job {}", r.id);
        } else {
            assert_ne!(r.stop_reason, StopReason::Deadline, "job {}", r.id);
        }
        assert!(r.dual_obj.is_finite());
    }

    for threads in [4usize, 8] {
        let (other, report, _engine) = run(threads);
        assert_eq!(report.deadline_stops, 4);
        for (a, b) in base.iter().zip(&other) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.iterations, b.iterations, "job {} at {threads} threads", a.id);
            assert_eq!(a.stop_reason, b.stop_reason, "job {} at {threads} threads", a.id);
            assert_eq!(
                a.dual_obj.to_bits(),
                b.dual_obj.to_bits(),
                "job {} at {threads} threads",
                a.id
            );
            for (x, y) in a.lam.iter().zip(&b.lam) {
                assert_eq!(x.to_bits(), y.to_bits(), "job {} λ at {threads} threads", a.id);
            }
        }
    }

    // the deadline-killed pattern (seed 203) still warmed the cache: a
    // re-solve of the same pattern starts warm
    let again = base_engine.submit(SolveJob::new(99, family_lp(ProjectionKind::Simplex, 203)));
    assert!(again.warm, "deadline-stopped job must warm its successor");
    assert!(base_engine.stats().deadline_stops >= 4);
}

#[test]
fn deadline_stop_publishes_usable_warm_start_duals() {
    // run a full cold solve for the iteration baseline, then a
    // deadline-killed solve of the same pattern on a fresh engine, then a
    // full re-solve: the re-solve must start warm from the killed job's
    // published λ and reach the matched stopping criterion
    let cold_engine = SolveEngine::new(coop_cfg(1));
    let cold = cold_engine.submit(SolveJob::new(0, family_lp(ProjectionKind::Simplex, 300)));
    assert!(!cold.warm);

    let engine = SolveEngine::new(coop_cfg(2));
    let job = SolveJob::new(1, family_lp(ProjectionKind::Simplex, 300)).with_deadline_ms(0.0);
    let (killed, report) = engine.solve_batch_coop(vec![job]);
    assert_eq!(report.deadline_stops, 1);
    assert_eq!(killed[0].stop_reason, StopReason::Deadline);
    assert!(killed[0].iterations >= 1);

    let warm = engine.submit(SolveJob::new(2, family_lp(ProjectionKind::Simplex, 300)));
    assert!(warm.warm, "killed solve must have published a warm start");
    assert_ne!(warm.stop_reason, StopReason::Deadline, "no deadline on the re-solve");
    assert!(warm.dual_obj.is_finite());
    // same pattern ⇒ same optimum: the re-solve lands on the cold answer,
    // which is what makes the published dual "usable"
    let rel = (warm.dual_obj - cold.dual_obj).abs() / cold.dual_obj.abs().max(1.0);
    assert!(rel < 1e-2, "warm {} vs cold {} (rel {rel})", warm.dual_obj, cold.dual_obj);
}
