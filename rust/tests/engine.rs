//! Engine-layer integration (experiment E12): warm-started repeated
//! solving on perturbation streams, batch-scheduler determinism, and the
//! acceptance protocol — on a generated perturbation sequence (same `A`
//! pattern, perturbed `c`/`b`) the warm-started solve reaches the matched
//! stopping criterion in measurably fewer iterations than the cold solve,
//! and `solve_batch` across ≥ 8 concurrent jobs is bit-identical to
//! sequential execution.

use dualip::engine::{EngineConfig, Fingerprint, SolveEngine, SolveJob};
use dualip::gen::workloads::{perturbation_sequence, PerturbSpec};
use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{jacobi_row_normalize, MatchingLp};
use dualip::solver::{GammaSchedule, SolveOptions, StopReason, StoppingCriteria};

/// Conditioned base instance for the stream (the paper's standard §5.1
/// pipeline; conditioning commutes with the c/b perturbation because the
/// row scaling depends only on A, which the stream shares).
fn base_instance(seed: u64) -> MatchingLp {
    let mut lp = generate(&SyntheticConfig {
        num_requests: 1_200,
        num_resources: 60,
        avg_nnz_per_row: 6.0,
        seed,
        ..Default::default()
    });
    jacobi_row_normalize(&mut lp);
    lp
}

/// Matched stopping criterion: objective stall at the floor γ. The raw
/// gradient norm does NOT vanish at a constrained dual optimum (slack rows
/// pin λ = 0 against a negative gradient), so stall — not grad tolerance —
/// is the reachable criterion for matching LPs.
fn stream_options() -> SolveOptions {
    SolveOptions {
        max_iters: 2_000,
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        gamma: GammaSchedule::paper_fig5(), // 0.16 → 0.01, floor at iter 100
        stopping: StoppingCriteria {
            stall_tol: Some(1e-6),
            stall_patience: 10,
            ..Default::default()
        },
        record_every: 500,
    }
}

fn engine(threads: usize, cache_capacity: usize) -> SolveEngine {
    SolveEngine::new(EngineConfig {
        opts: stream_options(),
        warm_tail: 5,
        threads,
        cache_capacity,
        backend: dualip::backend::CpuBackend::Slab,
        objective_threads: 1,
        shards: 1,
        deadline_ms: None,
        quantum: 16,
    })
}

const STREAM_SEED: u64 = 17;
const JOBS: usize = 10; // ≥ 8 per the acceptance criteria

fn stream_jobs(spec: &PerturbSpec) -> Vec<SolveJob> {
    let base = base_instance(STREAM_SEED);
    perturbation_sequence(&base, spec, JOBS, 1000)
        .into_iter()
        .enumerate()
        .map(|(k, lp)| SolveJob::new(k as u64, lp))
        .collect()
}

#[test]
fn warm_resolve_beats_cold_at_matched_stopping() {
    let spec = PerturbSpec { c_rel: 0.03, b_rel: 0.03 };

    // cold baseline: zero-capacity cache ⇒ every solve from λ = 0
    let cold = engine(1, 0);
    let cold_results: Vec<_> =
        stream_jobs(&spec).into_iter().map(|j| cold.submit(j)).collect();

    // warm: primed on the base instance, then the stream
    let warm = engine(8, 16);
    let primer = warm.submit(SolveJob::new(u64::MAX, base_instance(STREAM_SEED)));
    assert!(!primer.warm);
    let (warm_results, report) = warm.solve_batch(stream_jobs(&spec));
    assert_eq!(report.jobs, JOBS);

    let mut cold_total = 0usize;
    let mut warm_total = 0usize;
    for (c, w) in cold_results.iter().zip(&warm_results) {
        // both reach the SAME criterion (stall at floor γ), neither the
        // iteration-budget fallback
        assert_eq!(c.stop_reason, StopReason::ObjectiveStall, "cold job {}", c.id);
        assert_eq!(w.stop_reason, StopReason::ObjectiveStall, "warm job {}", w.id);
        assert_eq!(c.final_gamma, 0.01);
        assert_eq!(w.final_gamma, 0.01);
        assert!(w.warm, "job {} should warm-start", w.id);
        // same instance ⇒ same optimum: objectives agree within tolerance
        let rel = (c.dual_obj - w.dual_obj).abs() / c.dual_obj.abs().max(1.0);
        assert!(
            rel < 5e-3,
            "job {}: cold obj {} vs warm obj {} (rel {rel})",
            c.id,
            c.dual_obj,
            w.dual_obj
        );
        // warm takes fewer iterations on every job — the cold path cannot
        // even evaluate its criterion before the γ floor (iter 100), while
        // the warm path re-smooths over a 5-iteration tail
        assert!(
            w.iterations < c.iterations,
            "job {}: warm {} !< cold {}",
            w.id,
            w.iterations,
            c.iterations
        );
        cold_total += c.iterations;
        warm_total += w.iterations;
    }
    // aggregate: measurably fewer — at least 2× fewer iterations
    assert!(
        (warm_total as f64) < 0.5 * cold_total as f64,
        "warm {warm_total} vs cold {cold_total} total iterations"
    );
}

#[test]
fn solve_batch_concurrent_equals_sequential_bitwise() {
    let spec = PerturbSpec { c_rel: 0.05, b_rel: 0.05 };

    // two engines with identical configs except pool width, primed
    // identically — every per-job computation is a pure function of
    // (instance, snapshot warm start, options), so trajectories' final λ
    // must agree bit-for-bit
    let par = engine(8, 16);
    let seq = engine(1, 16);
    let p1 = par.submit(SolveJob::new(u64::MAX, base_instance(STREAM_SEED)));
    let p2 = seq.submit(SolveJob::new(u64::MAX, base_instance(STREAM_SEED)));
    assert_eq!(p1.lam, p2.lam, "primers must agree bitwise");

    let (a, report_a) = par.solve_batch(stream_jobs(&spec));
    let (b, _report_b) = seq.solve_batch(stream_jobs(&spec));
    assert_eq!(a.len(), JOBS);
    assert!(report_a.threads >= 8.min(JOBS), "pool width {}", report_a.threads);
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.id, rb.id);
        assert_eq!(ra.iterations, rb.iterations, "job {}", ra.id);
        assert_eq!(ra.lam, rb.lam, "job {}: final λ must match bit-for-bit", ra.id);
        assert_eq!(ra.dual_obj, rb.dual_obj, "job {}", ra.id);
    }
}

#[test]
fn fingerprints_recognize_the_stream_and_reject_strangers() {
    let base = base_instance(3);
    let spec = PerturbSpec::default();
    let fp = Fingerprint::of(&base);
    for lp in perturbation_sequence(&base, &spec, 4, 7) {
        assert_eq!(Fingerprint::of(&lp), fp);
    }
    let other = base_instance(4);
    assert_ne!(Fingerprint::of(&other), fp);
}

#[test]
fn projection_spec_distinguishes_engine_fingerprints() {
    use dualip::projection::{ProjectionKind, ProjectionMap};

    // Two instances differing ONLY in projection spec: same sparsity,
    // same c/b — structurally distinct, so the warm-start LRU must not
    // serve one's dual to the other.
    let base = base_instance(6);
    let mut capped = base.clone();
    capped.projection = ProjectionMap::Uniform(ProjectionKind::capped_simplex(0.5, 1.0));
    let fp_base = Fingerprint::of(&base);
    let fp_capped = Fingerprint::of(&capped);
    assert_eq!(fp_base.pattern_hash, fp_capped.pattern_hash, "same A pattern");
    assert_ne!(fp_base, fp_capped, "polytope must be part of identity");

    // registry-parsed operators (incl. non-Copy-parameter families) too
    let mut weighted = base.clone();
    weighted.projection = ProjectionMap::Uniform(
        ProjectionKind::parse("weighted_simplex:1:1,2").unwrap(),
    );
    assert_ne!(Fingerprint::of(&weighted), fp_base);
    assert_ne!(Fingerprint::of(&weighted), fp_capped);

    // and the engine keeps them in separate cache slots
    let e = engine(1, 8);
    let r1 = e.submit(SolveJob::new(0, base));
    let r2 = e.submit(SolveJob::new(1, capped));
    assert!(!r1.warm && !r2.warm, "no cross-polytope warm start");
    assert_eq!(e.cache_len(), 2);
}

#[test]
fn engine_stats_track_the_serving_mix() {
    let spec = PerturbSpec { c_rel: 0.03, b_rel: 0.03 };
    let e = engine(4, 16);
    let _ = e.submit(SolveJob::new(u64::MAX, base_instance(STREAM_SEED)));
    let (_results, _report) = e.solve_batch(stream_jobs(&spec));
    let s = e.stats();
    assert_eq!(s.submitted, 1 + JOBS as u64);
    assert_eq!(s.cold_solves, 1);
    assert_eq!(s.warm_solves, JOBS as u64);
    assert!(s.mean_warm_iters() < s.mean_cold_iters());
    assert_eq!(s.batches, 1);
    assert!(
        s.objective_eval_ms > 0.0 && s.objective_eval_ms <= s.total_wall_ms,
        "objective eval {}ms must be a subset of total {}ms",
        s.objective_eval_ms,
        s.total_wall_ms
    );
    let (hits, misses) = e.cache_counters();
    assert_eq!(hits, JOBS as u64);
    assert_eq!(misses, 1);
}

#[test]
fn engine_jobs_run_on_the_slab_backend_by_default() {
    // construct through ..Default::default() so this actually guards the
    // default backend choice, not a hardcoded one
    let e = SolveEngine::new(EngineConfig {
        opts: stream_options(),
        warm_tail: 5,
        threads: 1,
        cache_capacity: 4,
        ..Default::default()
    });
    let r = e.submit(SolveJob::new(0, base_instance(STREAM_SEED)));
    assert_eq!(r.backend, "cpu-slab");
    assert!(r.objective_eval_ms > 0.0);
}
