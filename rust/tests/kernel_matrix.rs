//! Cross-backend kernel conformance matrix (DESIGN.md §12): every
//! registered projection family round-trips through every execution tier
//! and the tiers must agree.
//!
//! Tiers and their bars:
//! - **projection**: the family's `project_rows` slab kernel (batched
//!   override or scalar-loop default) must be *bit-identical* to the
//!   scalar `project` applied per row, with an exactly `+0.0` padding
//!   tail — randomized over widths 1..=64, masked tails, and degenerate
//!   (huge/tiny/empty) inputs.
//! - **objective**: slab, sharded-slab, and reference evaluations of the
//!   same LP must agree — slab bit-identical across thread counts,
//!   sharded bit-identical to single-shard slab at any shard count,
//!   reference within tight tolerance.
//! - **hlo**: `emit_hlo` must produce deterministic, well-formed slab
//!   modules; the builtin families' text is pinned byte-for-byte by the
//!   golden snapshots under `tests/snapshots/` (no XLA runtime is
//!   assumed here — execution equivalence is validated out-of-band).
//!
//! The matrix is registry-driven: it iterates `registry::families()`, so
//! a newly registered family is held to the same bar with zero edits
//! here (the audit rule R1 requires this file to stay cross-referenced
//! with the registry).

use std::any::Any;
use std::path::PathBuf;
use std::sync::Arc;

use dualip::backend::{ShardedSlabObjective, SlabCpuObjective};
use dualip::problem::{MatchingLp, ObjectiveFunction};
use dualip::projection::hlo::emission_is_well_formed;
use dualip::projection::{registry, BlockProjection, ProjectionKind, ProjectionMap};
use dualip::reference::CpuObjective;
use dualip::sparse::slabs::MAX_WIDTH;
use dualip::sparse::BlockedMatrix;
use dualip::util::rng::Rng;

/// Families the seed registry must always carry — the matrix refuses to
/// pass if one goes missing (a registry-driven loop over zero families
/// would vacuously succeed).
const REQUIRED_FAMILIES: [&str; 5] =
    ["box", "box_vec", "capped_simplex", "simplex", "weighted_simplex"];

/// Wrapper that erases a family's accelerated tiers: `project_rows`
/// falls through to the trait's scalar-loop default and `emit_hlo` to
/// `None`, while the scalar `project` and the oracles still delegate.
/// Comparing an op against its `ScalarOnly` shadow is exactly the
/// "batched override ≡ scalar default" contract.
struct ScalarOnly(Arc<dyn BlockProjection>);

impl BlockProjection for ScalarOnly {
    fn family(&self) -> &str {
        self.0.family()
    }
    fn spec(&self) -> String {
        self.0.spec()
    }
    fn project(&self, v: &mut [f32]) {
        self.0.project(v)
    }
    fn violation(&self, v: &[f32]) -> f64 {
        self.0.violation(v)
    }
    fn separable(&self) -> bool {
        self.0.separable()
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Every registered (family, sample) pair, parsed. The unit of iteration
/// for the whole matrix.
fn all_registered_kinds() -> Vec<(String, ProjectionKind)> {
    let mut out = Vec::new();
    for fam in registry::families() {
        let samples = registry::family_samples(&fam);
        assert!(!samples.is_empty(), "family {fam} has no conformance samples");
        for sample in samples {
            let kind = ProjectionKind::parse(&sample)
                .unwrap_or_else(|| panic!("sample {sample} of family {fam} must parse"));
            out.push((sample, kind));
        }
    }
    out
}

#[test]
fn registry_still_carries_every_required_family() {
    let fams = registry::families();
    for req in REQUIRED_FAMILIES {
        assert!(fams.iter().any(|f| f == req), "family {req} missing from registry: {fams:?}");
    }
}

// ---------------------------------------------------------------------------
// projection tier
// ---------------------------------------------------------------------------

/// Fill a rows×width slab the way `gather_project` would: real prefixes
/// carry arbitrary values, padding tails carry the mask-multiplied ±0.0
/// (the sign bit is preserved by the gather, so exercise both signs).
fn random_masked_slab(
    rng: &mut Rng,
    rows: usize,
    width: usize,
    value: &mut dyn FnMut(&mut Rng) -> f32,
) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
    let mut slab = vec![0.0f32; rows * width];
    let mut mask = vec![0.0f32; rows * width];
    let mut reals = Vec::with_capacity(rows);
    for r in 0..rows {
        // include the empty row (all padding) and the full row
        let real = rng.below(width + 1);
        reals.push(real);
        for c in 0..width {
            let i = r * width + c;
            if c < real {
                slab[i] = value(rng);
                mask[i] = 1.0;
            } else {
                slab[i] = if rng.below(2) == 0 { -0.0 } else { 0.0 };
            }
        }
    }
    (slab, mask, reals)
}

fn assert_rows_match_scalar(
    kind: ProjectionKind,
    slab: &[f32],
    mask: &[f32],
    reals: &[usize],
    rows: usize,
    width: usize,
    ctx: &str,
) {
    let op = kind.op();
    let scalar = ScalarOnly(op.clone());
    let mut got = slab.to_vec();
    op.project_rows(&mut got, rows, width, mask);
    let mut want = slab.to_vec();
    scalar.project_rows(&mut want, rows, width, mask);
    for r in 0..rows {
        for c in 0..width {
            let (a, b) = (got[r * width + c], want[r * width + c]);
            assert!(
                a.to_bits() == b.to_bits(),
                "{ctx}: row {r} col {c} (real {}): batched {a:?} ({:#010x}) vs scalar {b:?} ({:#010x})",
                reals[r],
                a.to_bits(),
                b.to_bits()
            );
            assert!(a.is_finite(), "{ctx}: row {r} col {c}: non-finite output {a}");
            if c >= reals[r] {
                // padding must be exactly +0.0 — a -0.0 tail would leak
                // through `primal_into` into user-visible output
                assert_eq!(a.to_bits(), 0, "{ctx}: padding row {r} col {c} is {a:?}, not +0.0");
            }
        }
    }
}

/// The headline projection-tier property: for every registered family
/// and sample, the batched `project_rows` is bit-identical to the
/// scalar-loop default over randomized widths, masked padding tails
/// (both zero signs), and row counts — including empty and full rows.
#[test]
fn prop_project_rows_matches_scalar_default_for_every_family() {
    let mut rng = Rng::new(0xC0FFEE);
    for (sample, kind) in all_registered_kinds() {
        // fixed awkward widths plus a randomized sweep of 1..=64
        let mut widths = vec![1usize, 2, 5, 8];
        for _ in 0..6 {
            widths.push(1 + rng.below(64));
        }
        for width in widths {
            for case in 0..3 {
                let rows = 1 + rng.below(12);
                let (slab, mask, reals) = random_masked_slab(&mut rng, rows, width, &mut |g| {
                    (g.normal() * 2.0) as f32
                });
                let ctx = format!("{sample} w={width} case {case}");
                assert_rows_match_scalar(kind, &slab, &mask, &reals, rows, width, &ctx);
            }
        }
    }
}

/// Degenerate inputs stay NaN-free and bit-consistent: all-zero rows,
/// huge magnitudes, denormal-scale values, negative-only rows.
#[test]
fn prop_degenerate_inputs_stay_nan_free_and_consistent() {
    let mut rng = Rng::new(0xDE6E);
    let mut regimes: Vec<(&str, Box<dyn FnMut(&mut Rng) -> f32>)> = vec![
        ("zeros", Box::new(|_| 0.0)),
        ("huge", Box::new(|g| (g.normal() * 1e30) as f32)),
        ("tiny", Box::new(|g| (g.normal() * 1e-30) as f32)),
        ("negative", Box::new(|g| -(g.uniform() as f32) - 1e-3)),
    ];
    for (sample, kind) in all_registered_kinds() {
        for (regime, value) in regimes.iter_mut() {
            for width in [1usize, 3, 8, 17] {
                let rows = 1 + rng.below(6);
                let (slab, mask, reals) = random_masked_slab(&mut rng, rows, width, value);
                let ctx = format!("{sample} regime {regime} w={width}");
                assert_rows_match_scalar(kind, &slab, &mask, &reals, rows, width, &ctx);
            }
        }
    }
}

/// Every builtin family must carry a hand-vectorized batched override —
/// the scalar default is a compatibility fallback for runtime-registered
/// families, not a tier builtins are allowed to quietly drop to.
#[test]
fn builtin_families_carry_batched_overrides() {
    for fam in REQUIRED_FAMILIES {
        for sample in registry::family_samples(fam) {
            let kind = ProjectionKind::parse(&sample).unwrap();
            assert!(
                kind.op().batched_project_rows(),
                "builtin {sample} reports the scalar tier — its project_rows override \
                 must flip batched_project_rows() to true"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// objective tier
// ---------------------------------------------------------------------------

/// Random matching LP with the given per-source degrees (distinct dests),
/// uniform over `kind`.
fn lp_for_kind(
    rng: &mut Rng,
    kind: ProjectionKind,
    num_sources: usize,
    num_dests: usize,
) -> MatchingLp {
    let mut src_ptr = vec![0usize];
    let mut dest_idx: Vec<u32> = Vec::new();
    for _ in 0..num_sources {
        let deg = rng.below(10.min(num_dests) + 1);
        dest_idx.extend(rng.sample_distinct(num_dests, deg));
        src_ptr.push(dest_idx.len());
    }
    let nnz = dest_idx.len();
    let a = vec![(0..nnz).map(|_| (rng.uniform() * 2.0 + 0.05) as f32).collect::<Vec<f32>>()];
    let cost: Vec<f32> = (0..nnz).map(|_| -(rng.uniform() as f32) - 0.01).collect();
    let b: Vec<f32> = (0..num_dests).map(|_| (rng.uniform() * 2.0 + 0.01) as f32).collect();
    let m = BlockedMatrix {
        num_sources,
        num_dests,
        num_families: 1,
        src_ptr,
        dest_idx,
        a,
    };
    let lp = MatchingLp::new_uniform(m, cost, b, kind);
    lp.validate().unwrap();
    lp
}

/// One (family-sample, LP) cell of the objective matrix: slab threads
/// 1/2/4 bitwise-identical, sharded 2/3 bitwise-identical to slab-1,
/// reference within tight tolerance.
fn assert_objective_tiers_agree(lp: &MatchingLp, lam: &[f32], gamma: f32, ctx: &str) {
    let mut slab1 = SlabCpuObjective::new(lp, 1)
        .unwrap_or_else(|e| panic!("{ctx}: slab layout must build: {e}"));
    let r1 = slab1.calculate(lam, gamma);
    let x1 = slab1.primal(lam, gamma);

    for threads in [2usize, 4] {
        let mut slab = SlabCpuObjective::new(lp, threads).unwrap();
        let rt = slab.calculate(lam, gamma);
        assert_eq!(r1.dual_obj.to_bits(), rt.dual_obj.to_bits(), "{ctx}: dual_obj at {threads}t");
        assert_eq!(r1.cx.to_bits(), rt.cx.to_bits(), "{ctx}: cx at {threads}t");
        for (row, (a, b)) in r1.grad.iter().zip(&rt.grad).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: grad row {row} at {threads}t");
        }
        let xt = slab.primal(lam, gamma);
        for (e, (a, b)) in x1.iter().zip(&xt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: primal edge {e} at {threads}t");
        }
    }

    for shards in [2usize, 3] {
        let mut sh = ShardedSlabObjective::new(lp, shards, 1)
            .unwrap_or_else(|e| panic!("{ctx}: sharded plan must build: {e}"));
        let rs = sh.calculate(lam, gamma);
        assert_eq!(
            r1.dual_obj.to_bits(),
            rs.dual_obj.to_bits(),
            "{ctx}: sharded dual_obj at {shards} shards"
        );
        for (row, (a, b)) in r1.grad.iter().zip(&rs.grad).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: sharded grad row {row} at {shards} shards"
            );
        }
    }

    let mut reference = CpuObjective::new(lp);
    let rr = reference.calculate(lam, gamma);
    let close = |a: f64, b: f64, what: &str| {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "{ctx}: {what}: slab {a} vs reference {b}"
        );
    };
    close(r1.dual_obj, rr.dual_obj, "dual_obj");
    close(r1.cx, rr.cx, "cx");
    close(r1.xsq_weighted, rr.xsq_weighted, "xsq_weighted");
    for (row, (a, b)) in r1.grad.iter().zip(&rr.grad).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "{ctx}: grad row {row}: slab {a} vs reference {b}"
        );
    }
}

#[test]
fn prop_objective_matrix_over_every_registered_family() {
    let mut rng = Rng::new(0x5AB5);
    for (sample, kind) in all_registered_kinds() {
        for case in 0..2 {
            let (ns, nd) = (50 + rng.below(100), 8 + rng.below(16));
            let lp = lp_for_kind(&mut rng, kind, ns, nd);
            let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.3) as f32).collect();
            let gamma = if case == 0 { 0.05 } else { 0.3 };
            assert_objective_tiers_agree(&lp, &lam, gamma, &format!("{sample} case {case}"));
        }
    }
}

#[test]
fn objective_matrix_covers_overwide_split_rows() {
    // separable blocks wider than MAX_WIDTH split across slab rows; the
    // tiers must still agree through the split
    let mut rng = Rng::new(0x0BE5);
    let num_dests = MAX_WIDTH + 48;
    let kind = ProjectionKind::Box;
    let mut src_ptr = vec![0usize];
    let mut dest_idx: Vec<u32> = Vec::new();
    for deg in [MAX_WIDTH + 17, 4, MAX_WIDTH + 40, 1] {
        dest_idx.extend(rng.sample_distinct(num_dests, deg));
        src_ptr.push(dest_idx.len());
    }
    let nnz = dest_idx.len();
    let a = vec![(0..nnz).map(|_| (rng.uniform() * 2.0 + 0.05) as f32).collect::<Vec<f32>>()];
    let cost: Vec<f32> = (0..nnz).map(|_| -(rng.uniform() as f32) - 0.01).collect();
    let b: Vec<f32> = (0..num_dests).map(|_| (rng.uniform() * 2.0 + 0.01) as f32).collect();
    let m = BlockedMatrix { num_sources: 4, num_dests, num_families: 1, src_ptr, dest_idx, a };
    let lp = MatchingLp::new_uniform(m, cost, b, kind);
    lp.validate().unwrap();
    let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.3) as f32).collect();
    assert_objective_tiers_agree(&lp, &lam, 0.1, "overwide box");
}

/// The matrix is genuinely registry-driven: a family registered at
/// runtime — with no batched override and no HLO emission — is picked up
/// by the same loops and passes the projection + objective tiers through
/// the scalar default.
#[test]
fn runtime_registered_family_passes_the_matrix() {
    struct HalfCap;
    impl BlockProjection for HalfCap {
        fn family(&self) -> &str {
            "matrix_half_cap"
        }
        fn spec(&self) -> String {
            "matrix_half_cap".to_string()
        }
        fn project(&self, v: &mut [f32]) {
            for x in v.iter_mut() {
                *x = x.clamp(0.0, 0.5);
            }
        }
        fn violation(&self, v: &[f32]) -> f64 {
            v.iter().map(|&x| (x - 0.5).max(-x).max(0.0) as f64).fold(0.0, f64::max)
        }
        fn separable(&self) -> bool {
            true
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }
    registry::register_family("matrix_half_cap", &["matrix_half_cap"], |args| {
        args.is_empty().then(|| Box::new(HalfCap) as Box<dyn BlockProjection>)
    });
    let kind = ProjectionKind::parse("matrix_half_cap").unwrap();
    assert!(!kind.op().batched_project_rows(), "runtime family runs the scalar tier");
    assert!(kind.op().emit_hlo(4, 8).is_none(), "runtime family has no HLO emission");

    let mut rng = Rng::new(0xFA7);
    let (slab, mask, reals) =
        random_masked_slab(&mut rng, 6, 9, &mut |g| (g.normal() * 2.0) as f32);
    assert_rows_match_scalar(kind, &slab, &mask, &reals, 6, 9, "matrix_half_cap rows");

    let lp = lp_for_kind(&mut rng, kind, 60, 12);
    let lam: Vec<f32> = (0..lp.dual_dim()).map(|_| (rng.uniform() * 0.3) as f32).collect();
    assert_objective_tiers_agree(&lp, &lam, 0.1, "matrix_half_cap objective");
}

// ---------------------------------------------------------------------------
// hlo tier
// ---------------------------------------------------------------------------

/// Every registered family sample either emits a well-formed slab module
/// or declines (`None`) — and the builtins must all emit. Emission must
/// be deterministic: two calls produce identical text.
#[test]
fn hlo_emission_is_well_formed_and_deterministic_for_every_family() {
    for (sample, kind) in all_registered_kinds() {
        let op = kind.op();
        match op.emit_hlo(4, 8) {
            Some(text) => {
                assert!(
                    emission_is_well_formed(&text, 4, 8),
                    "{sample}: emission is malformed:\n{text}"
                );
                assert_eq!(op.emit_hlo(4, 8), Some(text), "{sample}: emission not deterministic");
            }
            None => {
                assert!(
                    !REQUIRED_FAMILIES.contains(&op.family()),
                    "builtin {sample} must emit HLO"
                );
            }
        }
        // degenerate tiles decline rather than emit garbage
        assert!(op.emit_hlo(0, 8).is_none(), "{sample}: rows=0 must decline");
        assert!(op.emit_hlo(4, 0).is_none(), "{sample}: width=0 must decline");
    }
}

/// Golden snapshots: the builtin emissions are pinned byte-for-byte under
/// `tests/snapshots/` (these exact texts were validated against XLA
/// compile-and-execute out-of-band). Set `DUALIP_REGEN_SNAPSHOTS=1` to
/// rewrite them after an intentional emitter change.
#[test]
fn hlo_golden_snapshots_pin_builtin_emission() {
    let specs = [
        ("simplex", "simplex"),
        ("box", "box"),
        ("capped_simplex:0.5:1", "capped_simplex"),
        ("weighted_simplex:2:1,2", "weighted_simplex"),
        ("box_vec:0.5,1.5", "box_vec"),
    ];
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("snapshots");
    let regen = std::env::var("DUALIP_REGEN_SNAPSHOTS").is_ok_and(|v| v == "1");
    for (spec, tag) in specs {
        let kind = ProjectionKind::parse(spec).unwrap_or_else(|| panic!("{spec} must parse"));
        for width in [4usize, 8] {
            let text = kind
                .op()
                .emit_hlo(4, width)
                .unwrap_or_else(|| panic!("{spec} must emit at w={width}"));
            let path = dir.join(format!("{tag}_t4_w{width}.hlo"));
            if regen {
                std::fs::write(&path, &text)
                    .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
                continue;
            }
            let pinned = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
            assert!(
                pinned == text,
                "stale HLO snapshot {}: the emitted module text changed.\n\
                 If the emitter change is intentional, regenerate with\n\
                 \n    DUALIP_REGEN_SNAPSHOTS=1 cargo test --test kernel_matrix\n\
                 \nand re-validate the new text against XLA before committing.\n\
                 --- pinned ---\n{pinned}\n--- emitted ---\n{text}",
                path.display()
            );
        }
    }
}
