//! Full-pipeline integration: generator → conditioning → slab/PJRT path →
//! distributed coordinator → primal recovery, exercised together (the E2E
//! composition the examples demo, as assertions). Requires artifacts
//! (`make artifacts`); tests self-skip otherwise.

use std::sync::Arc;

use dualip::distributed::{solve_distributed, DistributedObjective};
use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{check_primal, jacobi_row_normalize, ObjectiveFunction};
use dualip::runtime::{default_artifacts_dir, HloObjective};
use dualip::solver::{Agd, GammaSchedule, Maximizer, SolveOptions};

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

fn instance(seed: u64, m: usize) -> dualip::problem::MatchingLp {
    generate(&SyntheticConfig {
        num_requests: 1_500,
        num_resources: 80,
        avg_nnz_per_row: 7.0,
        num_families: m,
        seed,
        ..Default::default()
    })
}

#[test]
fn full_stack_solve_and_validate() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut lp = instance(11, 1);
    jacobi_row_normalize(&mut lp);
    let lp = Arc::new(lp);
    let opts = SolveOptions {
        max_iters: 250,
        gamma: GammaSchedule::paper_fig5(),
        max_step_size: 1.0,
        initial_step_size: 1e-4,
        ..Default::default()
    };
    let out = solve_distributed(lp.clone(), default_artifacts_dir(), 3, &opts).unwrap();
    // dual objective increased substantially and infeasibility fell
    let first = &out.result.trajectory[0];
    let last = out.result.trajectory.last().unwrap();
    assert!(last.dual_obj > first.dual_obj);
    assert!(last.infeas_pos_norm < first.infeas_pos_norm);

    // primal report sane
    let mut single = HloObjective::new(&lp, default_artifacts_dir()).unwrap();
    let x = single.primal(&out.result.lam, out.result.final_gamma);
    let rep = check_primal(&lp, &x, 1e-3);
    assert!(rep.simple_infeas_max < 1e-4);
    assert!(rep.complex_infeas.is_finite());

    // comm pattern: 2 bcasts + 1 reduce per iteration (+1 spawn bcast)
    assert_eq!(out.comm.reduce_ops, out.result.iterations as u64);
    assert_eq!(out.comm.bcast_ops, 2 * out.result.iterations as u64 + 1);
}

#[test]
fn multi_family_distributed_matches_cpu() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let lp = Arc::new(instance(12, 3));
    let lam: Vec<f32> = (0..lp.dual_dim()).map(|i| (i % 5) as f32 * 0.01).collect();
    let mut dist = DistributedObjective::new(lp.clone(), default_artifacts_dir(), 2).unwrap();
    let mut cpu = dualip::reference::CpuObjective::new(&lp);
    let rd = dist.calculate(&lam, 0.05);
    let rc = cpu.calculate(&lam, 0.05);
    assert!((rd.dual_obj - rc.dual_obj).abs() / rc.dual_obj.abs().max(1.0) < 1e-4);
    for (a, b) in rd.grad.iter().zip(&rc.grad) {
        assert!((a - b).abs() < 3e-3 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

#[test]
fn global_rows_work_through_the_full_distributed_stack() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut lp = instance(13, 1);
    let cap = 0.4 * lp.num_sources() as f32;
    lp.push_global_row(vec![1.0; lp.nnz()], cap);
    let lp = Arc::new(lp);
    let opts = SolveOptions {
        max_iters: 300,
        gamma: GammaSchedule::Fixed(0.01),
        max_step_size: 1e-2,
        ..Default::default()
    };
    let out = solve_distributed(lp.clone(), default_artifacts_dir(), 2, &opts).unwrap();
    let mut single = HloObjective::new(&lp, default_artifacts_dir()).unwrap();
    let x = single.primal(&out.result.lam, out.result.final_gamma);
    let total: f64 = x.iter().map(|&v| v as f64).sum();
    assert!(
        total <= cap as f64 * 1.05,
        "global row not enforced: Σx = {total} vs cap {cap}"
    );
    // and the dual dimension includes the extra row
    assert_eq!(out.result.lam.len(), lp.dual_dim());
    assert_eq!(lp.dual_dim(), lp.matching_dual_dim() + 1);
}

#[test]
fn primal_scaling_through_hlo_backend_solves() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut lp = instance(14, 1);
    dualip::problem::apply_primal_scaling(&mut lp);
    let mut obj = HloObjective::new(&lp, default_artifacts_dir()).unwrap();
    let opts = SolveOptions {
        max_iters: 150,
        gamma: GammaSchedule::Fixed(0.05),
        max_step_size: 1e-2,
        ..Default::default()
    };
    let r = Agd::default().maximize(&mut obj, &vec![0.0; lp.dual_dim()], &opts);
    let first = &r.trajectory[0];
    let last = r.trajectory.last().unwrap();
    assert!(last.dual_obj > first.dual_obj);
    // x respects the simple constraints exactly despite the scaled ridge
    let x = obj.primal(&r.lam, 0.05);
    let rep = check_primal(&lp, &x, 1e-3);
    assert!(rep.simple_infeas_max < 1e-4);
}

#[test]
fn failure_injection_worker_error_surfaces() {
    // bad artifacts directory → constructor error, not a hang/panic
    let lp = Arc::new(instance(15, 1));
    let r = DistributedObjective::new(lp, "/does/not/exist", 3);
    assert!(r.is_err());
    let msg = format!("{:#}", r.err().unwrap());
    assert!(msg.contains("artifacts") || msg.contains("manifest"), "{msg}");
}

#[test]
fn mixed_projection_map_through_hlo_backend() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // half the sources use box, half simplex — exercises multi-kind buckets
    let mut lp = instance(16, 1);
    lp.projection = dualip::projection::ProjectionMap::per_block(|i| {
        if i % 2 == 0 {
            dualip::projection::ProjectionKind::Simplex
        } else {
            dualip::projection::ProjectionKind::Box
        }
    });
    let mut hlo = HloObjective::new(&lp, default_artifacts_dir()).unwrap();
    let mut cpu = dualip::reference::CpuObjective::new(&lp);
    let lam = vec![0.02f32; lp.dual_dim()];
    let rh = hlo.calculate(&lam, 0.05);
    let rc = cpu.calculate(&lam, 0.05);
    assert!((rh.dual_obj - rc.dual_obj).abs() / rc.dual_obj.abs().max(1.0) < 1e-4);
    for (a, b) in rh.grad.iter().zip(&rc.grad) {
        assert!((a - b).abs() < 3e-3 * (1.0 + a.abs()));
    }
}
