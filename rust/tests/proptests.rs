//! Property-based tests over the coordinator substrates (seeded generator
//! harness — no external proptest crate offline; cases are derived from a
//! deterministic RNG and shrunk-by-construction via small sizes).
//!
//! Invariants covered: projection operators (feasibility, idempotence,
//! non-expansiveness, optimality), gather/scatter adjointness, bucketing
//! partition/roundtrip, partitioner coverage, scaling equivalences.

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{jacobi_row_normalize, unscale_dual, ObjectiveFunction};
use dualip::projection::{
    project_simplex_eq, project_simplex_ineq, project_unit_box, ProjectionKind,
};
use dualip::reference::CpuObjective;
use dualip::sparse::slabs::SlabLayout;
use dualip::util::rng::Rng;

const CASES: usize = 200;

fn rand_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

#[test]
fn prop_projections_feasible_and_idempotent() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let n = 1 + rng.below(24);
        let scale = 10f64.powf(rng.uniform_range(-2.0, 2.0));
        let v = rand_vec(&mut rng, n, scale);

        // simplex-ineq
        let mut p = v.clone();
        project_simplex_ineq(&mut p);
        let s: f64 = p.iter().map(|&x| x as f64).sum();
        assert!(p.iter().all(|&x| x >= 0.0), "case {case}");
        assert!(s <= 1.0 + 1e-4 * scale.max(1.0), "case {case}: sum {s}");
        let mut p2 = p.clone();
        project_simplex_ineq(&mut p2);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() <= 1e-5 * scale.max(1.0) as f32, "case {case}");
        }

        // box
        let mut q = v.clone();
        project_unit_box(&mut q);
        assert!(q.iter().all(|&x| (0.0..=1.0).contains(&x)));

        // box-cut with random radius, applied through the registry handle
        // (capped_simplex at cap 1 — `project_box_cut` is its thin alias)
        let r = (rng.uniform() * n as f64) as f32 + 0.1;
        let mut bc = v.clone();
        ProjectionKind::capped_simplex(1.0, r).apply(&mut bc);
        let sbc: f64 = bc.iter().map(|&x| x as f64).sum();
        assert!(sbc <= r as f64 + 1e-3, "case {case}: {sbc} > {r}");
        assert!(bc.iter().all(|&x| (-1e-6..=1.0 + 1e-6).contains(&x)));
    }
}

#[test]
fn prop_projection_nonexpansive() {
    // ‖Π(u) − Π(v)‖ ≤ ‖u − v‖ for convex projections.
    let mut rng = Rng::new(202);
    for _ in 0..CASES {
        let n = 2 + rng.below(12);
        let u = rand_vec(&mut rng, n, 2.0);
        let v = rand_vec(&mut rng, n, 2.0);
        let d_in: f64 = u.iter().zip(&v).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let mut pu = u.clone();
        let mut pv = v.clone();
        project_simplex_ineq(&mut pu);
        project_simplex_ineq(&mut pv);
        let d_out: f64 = pu.iter().zip(&pv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(d_out <= d_in + 1e-6, "{d_out} > {d_in}");
    }
}

#[test]
fn prop_simplex_eq_hits_radius() {
    let mut rng = Rng::new(303);
    for _ in 0..CASES {
        let n = 1 + rng.below(16);
        let r = (rng.uniform() * 3.0 + 0.05) as f32;
        let mut v = rand_vec(&mut rng, n, 3.0);
        project_simplex_eq(&mut v, r);
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        assert!((s - r as f64).abs() < 1e-3, "sum {s} != {r}");
        assert!(v.iter().all(|&x| x >= 0.0));
    }
}

#[test]
fn prop_capped_simplex_oracle() {
    // Feasibility, idempotence and optimality of Π onto {0 ≤ x ≤ u, Σx ≤ s}
    // against random feasible probes (Π(v) minimizes ‖x − v‖). Applied
    // through interned registry handles — the path every backend uses.
    let mut rng = Rng::new(909);
    for case in 0..CASES {
        let n = 1 + rng.below(16);
        let cap = (rng.uniform() * 2.0 + 0.05) as f32;
        let total = (rng.uniform() * 3.0 + 0.05) as f32;
        let k = ProjectionKind::capped_simplex(cap, total);
        let v = rand_vec(&mut rng, n, 2.0);

        let mut p = v.clone();
        k.apply(&mut p);
        let s: f64 = p.iter().map(|&x| x as f64).sum();
        assert!(s <= total as f64 + 1e-3, "case {case}: Σ {s} > {total}");
        assert!(
            p.iter().all(|&x| (-1e-6..=cap + 1e-5).contains(&x)),
            "case {case}: coordinate outside [0, {cap}]: {p:?}"
        );
        assert!(k.feasible(&p, 1e-3), "case {case}: oracle disagrees");

        let mut p2 = p.clone();
        k.apply(&mut p2);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-4, "case {case}: not idempotent");
        }

        let d_star: f64 = v.iter().zip(&p).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        for _ in 0..30 {
            let mut y: Vec<f64> = (0..n).map(|_| rng.uniform() * cap as f64).collect();
            let sy: f64 = y.iter().sum();
            if sy > total as f64 {
                let scale = total as f64 / sy;
                y.iter_mut().for_each(|x| *x *= scale);
            }
            let d: f64 = v.iter().zip(&y).map(|(a, b)| (*a as f64 - b).powi(2)).sum();
            assert!(d_star <= d + 1e-4, "case {case}: probe beat projection");
        }
    }
}

#[test]
fn prop_capped_simplex_nonexpansive_and_reductions() {
    let mut rng = Rng::new(1010);
    // ‖Π(u) − Π(v)‖ ≤ ‖u − v‖ (convex projection)
    for _ in 0..CASES {
        let n = 2 + rng.below(10);
        let cap = (rng.uniform() * 1.5 + 0.1) as f32;
        let total = (rng.uniform() * 2.0 + 0.1) as f32;
        let k = ProjectionKind::capped_simplex(cap, total);
        let u = rand_vec(&mut rng, n, 2.0);
        let v = rand_vec(&mut rng, n, 2.0);
        let d_in: f64 = u.iter().zip(&v).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let mut pu = u.clone();
        let mut pv = v.clone();
        k.apply(&mut pu);
        k.apply(&mut pv);
        let d_out: f64 = pu.iter().zip(&pv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(d_out <= d_in + 1e-5, "{d_out} > {d_in}");
    }
    // cap ≥ total ⇒ the per-edge cap can never bind and the polytope is
    // {x ≥ 0, Σx ≤ total}; at total = 1 that is the simplex-ineq oracle.
    let k_loose = ProjectionKind::capped_simplex(1.5, 1.0);
    for _ in 0..50 {
        let n = 1 + rng.below(12);
        let v = rand_vec(&mut rng, n, 2.0);
        let mut a = v.clone();
        k_loose.apply(&mut a);
        let mut b = v.clone();
        project_simplex_ineq(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{a:?} vs {b:?}");
        }
    }
    // parse/spec round-trip of the parametrized kind (the engine stores
    // kinds in bucket and artifact maps by value)
    let k = ProjectionKind::capped_simplex(0.25, 2.0);
    assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
    assert_eq!(k.capped_params(), Some((0.25, 2.0)));
}

#[test]
fn prop_gather_scatter_adjoint_on_random_instances() {
    let mut rng = Rng::new(404);
    for case in 0..30 {
        let lp = generate(&SyntheticConfig {
            num_requests: 50 + rng.below(200),
            num_resources: 8 + rng.below(32),
            avg_nnz_per_row: 2.0 + rng.uniform() * 6.0,
            num_families: 1 + rng.below(3),
            seed: case as u64,
            ..Default::default()
        });
        let x = rand_vec(&mut rng, lp.nnz(), 1.0);
        let lam = rand_vec(&mut rng, lp.matching_dual_dim(), 1.0);
        let mut ax = vec![0.0f32; lp.matching_dual_dim()];
        lp.a.scatter_ax(&x, &mut ax);
        let mut atl = vec![0.0f32; lp.nnz()];
        lp.a.gather_dual(&lam, &mut atl);
        let lhs: f64 = ax.iter().zip(&lam).map(|(a, b)| *a as f64 * *b as f64).sum();
        let rhs: f64 = atl.iter().zip(&x).map(|(a, b)| *a as f64 * *b as f64).sum();
        let denom = lhs.abs().max(rhs.abs()).max(1.0);
        assert!((lhs - rhs).abs() / denom < 1e-4, "case {case}: {lhs} vs {rhs}");
    }
}

#[test]
fn prop_bucketing_partitions_every_edge_exactly_once() {
    let mut rng = Rng::new(505);
    for case in 0..30 {
        let lp = generate(&SyntheticConfig {
            num_requests: 100 + rng.below(400),
            num_resources: 16 + rng.below(64),
            avg_nnz_per_row: 1.0 + rng.uniform() * 12.0,
            seed: 1000 + case as u64,
            ..Default::default()
        });
        let layout =
            SlabLayout::build(&lp.a, &lp.cost, 0, lp.num_sources(), &|_| ProjectionKind::Simplex)
                .unwrap();
        let mut seen = vec![false; lp.nnz()];
        for bk in &layout.buckets {
            for (&eid, &m) in bk.edge_id.iter().zip(&bk.mask) {
                if m > 0.0 {
                    assert!(eid != u32::MAX);
                    assert!(!seen[eid as usize], "edge {eid} duplicated");
                    seen[eid as usize] = true;
                } else {
                    assert_eq!(eid, u32::MAX);
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: some edge missing");
        assert!(layout.padding_factor() < 2.5, "{}", layout.padding_factor());
    }
}

#[test]
fn prop_partitioner_covers_and_balances() {
    let mut rng = Rng::new(606);
    for _ in 0..50 {
        let n_src = 1 + rng.below(500);
        let mut ptr = vec![0usize];
        for _ in 0..n_src {
            ptr.push(ptr.last().unwrap() + rng.below(30));
        }
        let workers = 1 + rng.below(8);
        let shards = dualip::distributed::balanced_partition(&ptr, workers);
        assert_eq!(shards.len(), workers);
        assert_eq!(shards[0].0, 0);
        assert_eq!(shards.last().unwrap().1, n_src);
        for w in shards.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }
}

#[test]
fn prop_row_scaling_preserves_primal_and_scales_dual() {
    // For the unconstrained-dual map: objective at λ in the original system
    // equals objective at D⁻¹λ in the scaled system... we verify the
    // implementable contract: x*γ(λ_scaled) with A' equals x*γ(D λ_scaled)
    // with A (the primal map only sees Aᵀλ).
    let mut rng = Rng::new(707);
    for case in 0..20 {
        let lp = generate(&SyntheticConfig {
            num_requests: 60,
            num_resources: 12,
            avg_nnz_per_row: 4.0,
            seed: 2000 + case,
            ..Default::default()
        });
        let mut lp_scaled = generate(&SyntheticConfig {
            num_requests: 60,
            num_resources: 12,
            avg_nnz_per_row: 4.0,
            seed: 2000 + case,
            ..Default::default()
        });
        let scaling = jacobi_row_normalize(&mut lp_scaled);

        let lam_s = rand_vec(&mut rng, lp.dual_dim(), 0.5)
            .iter()
            .map(|v| v.abs())
            .collect::<Vec<f32>>();
        let lam_o = unscale_dual(&scaling, &lam_s);

        let gamma = 0.1f32;
        let mut obj_o = CpuObjective::new(&lp);
        let mut obj_s = CpuObjective::new(&lp_scaled);
        let x_o = obj_o.primal(&lam_o, gamma);
        let x_s = obj_s.primal(&lam_s, gamma);
        for (a, b) in x_o.iter().zip(&x_s) {
            assert!((a - b).abs() < 1e-4, "case {case}: {a} vs {b}");
        }
    }
}

#[test]
fn prop_rng_distribution_sanity() {
    // Kolmogorov-style coarse checks to catch seeding regressions.
    let mut rng = Rng::new(808);
    let mut buckets = [0usize; 10];
    for _ in 0..100_000 {
        buckets[(rng.uniform() * 10.0) as usize % 10] += 1;
    }
    for &b in &buckets {
        assert!((b as f64 - 10_000.0).abs() < 500.0, "{buckets:?}");
    }
}
