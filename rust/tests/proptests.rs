//! Property-based tests over the coordinator substrates (seeded generator
//! harness — no external proptest crate offline; cases are derived from a
//! deterministic RNG and shrunk-by-construction via small sizes).
//!
//! Invariants covered: projection operators (feasibility, idempotence,
//! non-expansiveness, optimality), gather/scatter adjointness, bucketing
//! partition/roundtrip, partitioner coverage, scaling equivalences.

use dualip::gen::{generate, SyntheticConfig};
use dualip::problem::{jacobi_row_normalize, unscale_dual, ObjectiveFunction};
use dualip::projection::{
    project_simplex_eq, project_simplex_ineq, project_unit_box, ProjectionKind,
};
use dualip::reference::CpuObjective;
use dualip::sparse::slabs::SlabLayout;
use dualip::util::rng::Rng;

const CASES: usize = 200;

fn rand_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

#[test]
fn prop_projections_feasible_and_idempotent() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let n = 1 + rng.below(24);
        let scale = 10f64.powf(rng.uniform_range(-2.0, 2.0));
        let v = rand_vec(&mut rng, n, scale);

        // simplex-ineq
        let mut p = v.clone();
        project_simplex_ineq(&mut p);
        let s: f64 = p.iter().map(|&x| x as f64).sum();
        assert!(p.iter().all(|&x| x >= 0.0), "case {case}");
        assert!(s <= 1.0 + 1e-4 * scale.max(1.0), "case {case}: sum {s}");
        let mut p2 = p.clone();
        project_simplex_ineq(&mut p2);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() <= 1e-5 * scale.max(1.0) as f32, "case {case}");
        }

        // box
        let mut q = v.clone();
        project_unit_box(&mut q);
        assert!(q.iter().all(|&x| (0.0..=1.0).contains(&x)));

        // box-cut with random radius, applied through the registry handle
        // (capped_simplex at cap 1 — `project_box_cut` is its thin alias)
        let r = (rng.uniform() * n as f64) as f32 + 0.1;
        let mut bc = v.clone();
        ProjectionKind::capped_simplex(1.0, r).apply(&mut bc);
        let sbc: f64 = bc.iter().map(|&x| x as f64).sum();
        assert!(sbc <= r as f64 + 1e-3, "case {case}: {sbc} > {r}");
        assert!(bc.iter().all(|&x| (-1e-6..=1.0 + 1e-6).contains(&x)));
    }
}

#[test]
fn prop_projection_nonexpansive() {
    // ‖Π(u) − Π(v)‖ ≤ ‖u − v‖ for convex projections.
    let mut rng = Rng::new(202);
    for _ in 0..CASES {
        let n = 2 + rng.below(12);
        let u = rand_vec(&mut rng, n, 2.0);
        let v = rand_vec(&mut rng, n, 2.0);
        let d_in: f64 = u.iter().zip(&v).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let mut pu = u.clone();
        let mut pv = v.clone();
        project_simplex_ineq(&mut pu);
        project_simplex_ineq(&mut pv);
        let d_out: f64 = pu.iter().zip(&pv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(d_out <= d_in + 1e-6, "{d_out} > {d_in}");
    }
}

#[test]
fn prop_simplex_eq_hits_radius() {
    let mut rng = Rng::new(303);
    for _ in 0..CASES {
        let n = 1 + rng.below(16);
        let r = (rng.uniform() * 3.0 + 0.05) as f32;
        let mut v = rand_vec(&mut rng, n, 3.0);
        project_simplex_eq(&mut v, r);
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        assert!((s - r as f64).abs() < 1e-3, "sum {s} != {r}");
        assert!(v.iter().all(|&x| x >= 0.0));
    }
}

#[test]
fn prop_capped_simplex_oracle() {
    // Feasibility, idempotence and optimality of Π onto {0 ≤ x ≤ u, Σx ≤ s}
    // against random feasible probes (Π(v) minimizes ‖x − v‖). Applied
    // through interned registry handles — the path every backend uses.
    let mut rng = Rng::new(909);
    for case in 0..CASES {
        let n = 1 + rng.below(16);
        let cap = (rng.uniform() * 2.0 + 0.05) as f32;
        let total = (rng.uniform() * 3.0 + 0.05) as f32;
        let k = ProjectionKind::capped_simplex(cap, total);
        let v = rand_vec(&mut rng, n, 2.0);

        let mut p = v.clone();
        k.apply(&mut p);
        let s: f64 = p.iter().map(|&x| x as f64).sum();
        assert!(s <= total as f64 + 1e-3, "case {case}: Σ {s} > {total}");
        assert!(
            p.iter().all(|&x| (-1e-6..=cap + 1e-5).contains(&x)),
            "case {case}: coordinate outside [0, {cap}]: {p:?}"
        );
        assert!(k.feasible(&p, 1e-3), "case {case}: oracle disagrees");

        let mut p2 = p.clone();
        k.apply(&mut p2);
        for (a, b) in p.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-4, "case {case}: not idempotent");
        }

        let d_star: f64 = v.iter().zip(&p).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        for _ in 0..30 {
            let mut y: Vec<f64> = (0..n).map(|_| rng.uniform() * cap as f64).collect();
            let sy: f64 = y.iter().sum();
            if sy > total as f64 {
                let scale = total as f64 / sy;
                y.iter_mut().for_each(|x| *x *= scale);
            }
            let d: f64 = v.iter().zip(&y).map(|(a, b)| (*a as f64 - b).powi(2)).sum();
            assert!(d_star <= d + 1e-4, "case {case}: probe beat projection");
        }
    }
}

#[test]
fn prop_capped_simplex_nonexpansive_and_reductions() {
    let mut rng = Rng::new(1010);
    // ‖Π(u) − Π(v)‖ ≤ ‖u − v‖ (convex projection)
    for _ in 0..CASES {
        let n = 2 + rng.below(10);
        let cap = (rng.uniform() * 1.5 + 0.1) as f32;
        let total = (rng.uniform() * 2.0 + 0.1) as f32;
        let k = ProjectionKind::capped_simplex(cap, total);
        let u = rand_vec(&mut rng, n, 2.0);
        let v = rand_vec(&mut rng, n, 2.0);
        let d_in: f64 = u.iter().zip(&v).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let mut pu = u.clone();
        let mut pv = v.clone();
        k.apply(&mut pu);
        k.apply(&mut pv);
        let d_out: f64 = pu.iter().zip(&pv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(d_out <= d_in + 1e-5, "{d_out} > {d_in}");
    }
    // cap ≥ total ⇒ the per-edge cap can never bind and the polytope is
    // {x ≥ 0, Σx ≤ total}; at total = 1 that is the simplex-ineq oracle.
    let k_loose = ProjectionKind::capped_simplex(1.5, 1.0);
    for _ in 0..50 {
        let n = 1 + rng.below(12);
        let v = rand_vec(&mut rng, n, 2.0);
        let mut a = v.clone();
        k_loose.apply(&mut a);
        let mut b = v.clone();
        project_simplex_ineq(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{a:?} vs {b:?}");
        }
    }
    // parse/spec round-trip of the parametrized kind (the engine stores
    // kinds in bucket and artifact maps by value)
    let k = ProjectionKind::capped_simplex(0.25, 2.0);
    assert_eq!(ProjectionKind::parse(&k.spec()), Some(k));
    assert_eq!(k.capped_params(), Some((0.25, 2.0)));
}

#[test]
fn prop_gather_scatter_adjoint_on_random_instances() {
    let mut rng = Rng::new(404);
    for case in 0..30 {
        let lp = generate(&SyntheticConfig {
            num_requests: 50 + rng.below(200),
            num_resources: 8 + rng.below(32),
            avg_nnz_per_row: 2.0 + rng.uniform() * 6.0,
            num_families: 1 + rng.below(3),
            seed: case as u64,
            ..Default::default()
        });
        let x = rand_vec(&mut rng, lp.nnz(), 1.0);
        let lam = rand_vec(&mut rng, lp.matching_dual_dim(), 1.0);
        let mut ax = vec![0.0f32; lp.matching_dual_dim()];
        lp.a.scatter_ax(&x, &mut ax);
        let mut atl = vec![0.0f32; lp.nnz()];
        lp.a.gather_dual(&lam, &mut atl);
        let lhs: f64 = ax.iter().zip(&lam).map(|(a, b)| *a as f64 * *b as f64).sum();
        let rhs: f64 = atl.iter().zip(&x).map(|(a, b)| *a as f64 * *b as f64).sum();
        let denom = lhs.abs().max(rhs.abs()).max(1.0);
        assert!((lhs - rhs).abs() / denom < 1e-4, "case {case}: {lhs} vs {rhs}");
    }
}

#[test]
fn prop_bucketing_partitions_every_edge_exactly_once() {
    let mut rng = Rng::new(505);
    for case in 0..30 {
        let lp = generate(&SyntheticConfig {
            num_requests: 100 + rng.below(400),
            num_resources: 16 + rng.below(64),
            avg_nnz_per_row: 1.0 + rng.uniform() * 12.0,
            seed: 1000 + case as u64,
            ..Default::default()
        });
        let layout =
            SlabLayout::build(&lp.a, &lp.cost, 0, lp.num_sources(), &|_| ProjectionKind::Simplex)
                .unwrap();
        let mut seen = vec![false; lp.nnz()];
        for bk in &layout.buckets {
            for (&eid, &m) in bk.edge_id.iter().zip(&bk.mask) {
                if m > 0.0 {
                    assert!(eid != u32::MAX);
                    assert!(!seen[eid as usize], "edge {eid} duplicated");
                    seen[eid as usize] = true;
                } else {
                    assert_eq!(eid, u32::MAX);
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: some edge missing");
        assert!(layout.padding_factor() < 2.5, "{}", layout.padding_factor());
    }
}

#[test]
fn prop_partitioner_covers_and_balances() {
    let mut rng = Rng::new(606);
    for _ in 0..50 {
        let n_src = 1 + rng.below(500);
        let mut ptr = vec![0usize];
        for _ in 0..n_src {
            ptr.push(ptr.last().unwrap() + rng.below(30));
        }
        let workers = 1 + rng.below(8);
        let shards = dualip::distributed::balanced_partition(&ptr, workers);
        assert_eq!(shards.len(), workers);
        assert_eq!(shards[0].0, 0);
        assert_eq!(shards.last().unwrap().1, n_src);
        for w in shards.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }
}

#[test]
fn prop_row_scaling_preserves_primal_and_scales_dual() {
    // For the unconstrained-dual map: objective at λ in the original system
    // equals objective at D⁻¹λ in the scaled system... we verify the
    // implementable contract: x*γ(λ_scaled) with A' equals x*γ(D λ_scaled)
    // with A (the primal map only sees Aᵀλ).
    let mut rng = Rng::new(707);
    for case in 0..20 {
        let lp = generate(&SyntheticConfig {
            num_requests: 60,
            num_resources: 12,
            avg_nnz_per_row: 4.0,
            seed: 2000 + case,
            ..Default::default()
        });
        let mut lp_scaled = generate(&SyntheticConfig {
            num_requests: 60,
            num_resources: 12,
            avg_nnz_per_row: 4.0,
            seed: 2000 + case,
            ..Default::default()
        });
        let scaling = jacobi_row_normalize(&mut lp_scaled);

        let lam_s = rand_vec(&mut rng, lp.dual_dim(), 0.5)
            .iter()
            .map(|v| v.abs())
            .collect::<Vec<f32>>();
        let lam_o = unscale_dual(&scaling, &lam_s);

        let gamma = 0.1f32;
        let mut obj_o = CpuObjective::new(&lp);
        let mut obj_s = CpuObjective::new(&lp_scaled);
        let x_o = obj_o.primal(&lam_o, gamma);
        let x_s = obj_s.primal(&lam_s, gamma);
        for (a, b) in x_o.iter().zip(&x_s) {
            assert!((a - b).abs() < 1e-4, "case {case}: {a} vs {b}");
        }
    }
}

#[test]
fn prop_serve_snapshot_round_trips_across_all_projection_families() {
    // The durable warm-start snapshot (serve/snapshot.rs) must round-trip
    // bit-identically for every registered projection family: the encoded
    // bytes re-encode byte-for-byte after a decode, the cache entries keep
    // their exact λ/γ bits and LRU ticks, and a decoded mid-solve
    // checkpoint resumes to the same iteration count, stop reason,
    // trajectory and final λ as the in-memory checkpoint it was copied
    // from.
    use dualip::engine::{Fingerprint, WarmStartCache};
    use dualip::projection::registry;
    use dualip::serve::snapshot::{self, CheckpointEntry};
    use dualip::solver::{
        Agd, DriverOptions, GammaSchedule, SolveDriver, SolveOptions, StepEvent,
    };

    let families = registry::families();
    assert!(!families.is_empty());
    let mut rng = Rng::new(1111);
    for family in families {
        // bare name → family defaults; parameterized families fall back to
        // their registered conformance sample
        let kind = ProjectionKind::parse(&family)
            .or_else(|| {
                registry::family_samples(&family)
                    .first()
                    .and_then(|s| ProjectionKind::parse(s))
            })
            .unwrap_or_else(|| panic!("family {family} has no parseable spec"));
        for case in 0u64..3 {
            let lp = generate(&SyntheticConfig {
                num_requests: 60 + rng.below(60),
                num_resources: 8 + rng.below(8),
                avg_nnz_per_row: 3.0 + rng.uniform() * 3.0,
                kind,
                seed: 3000 + case,
                ..Default::default()
            });
            let fp = Fingerprint::of(&lp);
            let opts = SolveOptions {
                max_iters: 30 + rng.below(20),
                gamma: GammaSchedule::Decay { init: 0.08, floor: 0.02, factor: 0.5, every: 7 },
                ..Default::default()
            };
            let init = vec![0.0f32; lp.dual_dim()];
            let mut obj = CpuObjective::new(&lp);
            let mut driver = SolveDriver::new(
                Box::new(Agd::default().stepper()),
                &init,
                opts,
                DriverOptions::default(),
            );
            for _ in 0..5 + rng.below(10) {
                if let StepEvent::Stopped { .. } = driver.step(&mut obj) {
                    panic!("family {family}: solve stopped before the pause point");
                }
            }
            let ck = driver.checkpoint().expect("AGD steppers always checkpoint");

            let mut cache = WarmStartCache::new(4);
            cache.insert(fp, driver.current_lam().to_vec(), 0.05);
            let _ = cache.lookup(&fp);

            let entry =
                CheckpointEntry { request_id: case, fingerprint: fp, checkpoint: ck.clone() };
            let bytes = snapshot::encode(&cache, &[entry]).unwrap();
            let snap = snapshot::decode(&bytes).unwrap();
            let again = snapshot::encode(&snap.cache, &snap.checkpoints).unwrap();
            assert_eq!(bytes, again, "family {family}: re-encode not byte-identical");

            assert_eq!(snap.cache.tick(), cache.tick(), "family {family}");
            let (ea, eb) = (cache.export_entries(), snap.cache.export_entries());
            assert_eq!(ea.len(), eb.len());
            for ((fa, wa, ta), (fb, wb, tb)) in ea.iter().zip(&eb) {
                assert_eq!((fa, ta), (fb, tb), "family {family}");
                assert_eq!(wa.gamma.to_bits(), wb.gamma.to_bits());
                assert_eq!(wa.refreshes, wb.refreshes);
                assert_eq!(wa.lam.len(), wb.lam.len());
                for (x, y) in wa.lam.iter().zip(&wb.lam) {
                    assert_eq!(x.to_bits(), y.to_bits(), "family {family}: cached λ bits");
                }
            }

            // finish the solve twice: from the in-memory checkpoint and
            // from the decoded one — they must be indistinguishable
            let decoded = snap.checkpoints.into_iter().next().unwrap();
            assert_eq!(decoded.request_id, case);
            assert_eq!(decoded.fingerprint, fp);
            let mut obj_a = CpuObjective::new(&lp);
            let mut obj_b = CpuObjective::new(&lp);
            let mut da = SolveDriver::resume(ck);
            let mut db = SolveDriver::resume(decoded.checkpoint);
            while !matches!(da.step(&mut obj_a), StepEvent::Stopped { .. }) {}
            while !matches!(db.step(&mut obj_b), StepEvent::Stopped { .. }) {}
            let (ra, rb) = (da.result(&mut obj_a), db.result(&mut obj_b));
            assert_eq!(ra.iterations, rb.iterations, "family {family}");
            assert_eq!(ra.stop_reason, rb.stop_reason, "family {family}");
            assert_eq!(
                ra.final_obj.dual_obj.to_bits(),
                rb.final_obj.dual_obj.to_bits(),
                "family {family}: objective diverged after decode"
            );
            assert_eq!(ra.trajectory.len(), rb.trajectory.len());
            for (x, y) in ra.trajectory.iter().zip(&rb.trajectory) {
                assert_eq!(x.iter, y.iter);
                assert_eq!(x.dual_obj.to_bits(), y.dual_obj.to_bits());
            }
            for (x, y) in ra.lam.iter().zip(&rb.lam) {
                assert_eq!(x.to_bits(), y.to_bits(), "family {family}: λ diverged");
            }
        }
    }
}

#[test]
fn prop_rng_distribution_sanity() {
    // Kolmogorov-style coarse checks to catch seeding regressions.
    let mut rng = Rng::new(808);
    let mut buckets = [0usize; 10];
    for _ in 0..100_000 {
        buckets[(rng.uniform() * 10.0) as usize % 10] += 1;
    }
    for &b in &buckets {
        assert!((b as f64 - 10_000.0).abs() < 500.0, "{buckets:?}");
    }
}

#[test]
fn prop_parallel_build_bit_identical_across_families() {
    // Tentpole determinism gate (DESIGN.md §11): the chunk-parallel
    // counting-sort build must be bit-identical to the serial build at
    // every fill-pool width, for every registered projection family and
    // both width policies — including split over-wide separable sources —
    // and the pow2 serial build must reproduce the legacy `build` exactly.
    use dualip::projection::registry;
    use dualip::sparse::slabs::{BuildOptions, WidthPolicy, MAX_WIDTH};
    use dualip::sparse::BlockedMatrix;

    let families = registry::families();
    assert!(!families.is_empty());
    let mut rng = Rng::new(1212);
    for family in &families {
        let kind = ProjectionKind::parse(family)
            .or_else(|| {
                registry::family_samples(family)
                    .first()
                    .and_then(|s| ProjectionKind::parse(s))
            })
            .unwrap_or_else(|| panic!("family {family} has no parseable spec"));
        for case in 0..4 {
            let n = 40 + rng.below(120);
            let num_dests = 4 * MAX_WIDTH;
            let mut src_ptr = vec![0usize];
            for _ in 0..n {
                let roll = rng.below(12);
                let deg = if roll == 0 {
                    0 // empty sources must be skipped without a kind lookup
                } else if roll == 1 && kind.separable() {
                    MAX_WIDTH + 1 + rng.below(2 * MAX_WIDTH) // row-split path
                } else if roll < 6 {
                    1 + rng.below(9)
                } else {
                    1 + rng.below(80)
                };
                src_ptr.push(src_ptr.last().unwrap() + deg);
            }
            let nnz = *src_ptr.last().unwrap();
            let dest_idx: Vec<u32> = (0..nnz).map(|_| rng.below(num_dests) as u32).collect();
            let m = 1 + rng.below(2);
            let a: Vec<Vec<f32>> = (0..m).map(|_| rand_vec(&mut rng, nnz, 1.0)).collect();
            let cost = rand_vec(&mut rng, nnz, 1.0);
            let mat = BlockedMatrix {
                num_sources: n,
                num_dests,
                num_families: m,
                src_ptr,
                dest_idx,
                a,
            };
            let kind_of = |_: usize| kind;

            let legacy = SlabLayout::build(&mat, &cost, 0, n, &kind_of).unwrap();
            for policy in [WidthPolicy::Pow2, WidthPolicy::QuarterStep] {
                let serial = SlabLayout::build_opts(
                    &mat,
                    &cost,
                    0,
                    n,
                    &kind_of,
                    BuildOptions { policy, threads: 0 },
                )
                .unwrap();
                if policy == WidthPolicy::Pow2 {
                    if let Err(e) = legacy.bit_eq(&serial) {
                        panic!("family {family} case {case}: legacy vs serial: {e}");
                    }
                }
                for threads in [1usize, 2, 4, 8] {
                    let par = SlabLayout::build_opts(
                        &mat,
                        &cost,
                        0,
                        n,
                        &kind_of,
                        BuildOptions { policy, threads },
                    )
                    .unwrap();
                    if let Err(e) = par.bit_eq(&serial) {
                        panic!(
                            "family {family} case {case} {} {threads} threads: {e}",
                            policy.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_repacked_layout_matches_from_scratch_rebuild() {
    // The repack engine shares the fill pipeline with the full build, so a
    // layout mutated through `patch_edge_indexed` must stay bit-identical
    // to a from-scratch rebuild of the mutated instance after EVERY edit
    // (insert into headroom, width-crossing repack, source entry/removal),
    // with the resident inverted index in exact sync throughout.
    use dualip::projection::registry;
    use dualip::sparse::slabs::BuildOptions;
    use dualip::sparse::{SlabIndex, WidthPolicy};

    let families = registry::families();
    let mut rng = Rng::new(1313);
    for family in &families {
        let kind = ProjectionKind::parse(family)
            .or_else(|| {
                registry::family_samples(family)
                    .first()
                    .and_then(|s| ProjectionKind::parse(s))
            })
            .unwrap_or_else(|| panic!("family {family} has no parseable spec"));
        for case in 0u64..3 {
            let mut lp = generate(&SyntheticConfig {
                num_requests: 60 + rng.below(100),
                num_resources: 10 + rng.below(20),
                avg_nnz_per_row: 2.0 + rng.uniform() * 6.0,
                kind,
                seed: 5000 + case,
                ..Default::default()
            });
            let policy =
                if rng.below(2) == 0 { WidthPolicy::Pow2 } else { WidthPolicy::QuarterStep };
            let opts = BuildOptions { policy, threads: 0 };
            let mut layout = SlabLayout::build_opts(
                &lp.a,
                &lp.cost,
                0,
                lp.num_sources(),
                &|i| lp.projection.kind_of(i),
                opts,
            )
            .unwrap();
            let mut index = SlabIndex::build(&layout, 0, lp.num_sources());

            for edit in 0..12 {
                let s = rng.below(lp.num_sources());
                let deg = lp.a.src_ptr[s + 1] - lp.a.src_ptr[s];
                let k = lp.projection.kind_of(s);
                let insert = deg == 0 || (deg < lp.num_dests() && rng.below(2) == 0);
                if insert {
                    let avals = rand_vec(&mut rng, lp.num_families(), 1.0);
                    let cval = rng.normal() as f32;
                    let mut dest = rng.below(lp.num_dests()) as u32;
                    let p = loop {
                        match lp.insert_edge(s, dest, &avals, cval) {
                            Ok(p) => break p,
                            Err(_) => dest = (dest + 1) % lp.num_dests() as u32,
                        }
                    };
                    layout
                        .patch_edge_indexed(&lp.a, &lp.cost, s, p, true, k, &mut index)
                        .unwrap();
                } else {
                    let col = rng.below(deg);
                    let dest = lp.a.dest_idx[lp.a.src_ptr[s] + col];
                    let p = lp.remove_edge(s, dest).unwrap();
                    layout
                        .patch_edge_indexed(&lp.a, &lp.cost, s, p, false, k, &mut index)
                        .unwrap();
                }
                let fresh = SlabLayout::build_opts(
                    &lp.a,
                    &lp.cost,
                    0,
                    lp.num_sources(),
                    &|i| lp.projection.kind_of(i),
                    opts,
                )
                .unwrap();
                if let Err(e) = layout.bit_eq(&fresh) {
                    panic!("family {family} case {case} edit {edit}: {e}");
                }
                if let Err(e) = index.parity_check(&layout) {
                    panic!("family {family} case {case} edit {edit}: index: {e}");
                }
            }
        }
    }
}
