//! Offline shim of the `anyhow` API surface this repo uses: `Error`,
//! `Result`, `anyhow!`, `bail!`, `ensure!`, `Context::{context,
//! with_context}`, `Error::msg`, plus the `{e}` / `{e:#}` / `{e:?}`
//! formatting conventions. Unlike the
//! real crate it stores the cause chain as strings (no backtraces, no
//! downcasting) — enough for error propagation and reporting in a no-network
//! build. Replace with crates.io `anyhow = "1"` when vendoring is unneeded.

use std::fmt;

/// Error with a context chain; frame 0 is the outermost context.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (mirror of `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (mirror of `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// Innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (anyhow convention)
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames[0])?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.frames[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into Error (same blanket as real anyhow; Error
// itself does not implement std::error::Error, so no impl overlap).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as frames.
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result (mirror of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string / expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return an error (provided for parity; unused paths cost nothing).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an error unless the condition holds (mirror of
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn parse(s: &str) -> Result<i32> {
            let v: i32 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io fail"));
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: io fail");
        let o: Option<i32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn ensure_macro_forms() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v >= 0);
            ensure!(v < 100, "too big: {v}");
            Ok(v)
        }
        assert_eq!(check(42).unwrap(), 42);
        assert!(format!("{}", check(-1).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{}", check(200).unwrap_err()), "too big: 200");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let n = 7;
        let b = anyhow!("captured {n}");
        assert_eq!(format!("{b}"), "captured 7");
        let c = anyhow!("args {}", 9);
        assert_eq!(format!("{c}"), "args 9");
        let d = anyhow!(String::from("owned"));
        assert_eq!(format!("{d}"), "owned");
    }
}
