//! Offline shim of the tiny `libc` surface this repo uses: `timespec`,
//! `clock_gettime`, and `CLOCK_THREAD_CPUTIME_ID` (per-thread CPU time for
//! the modeled-parallel worker timing). Linux x86-64/aarch64 layout.
//! Replace with crates.io `libc = "0.2"` when vendoring is unneeded.

#![allow(non_camel_case_types)]

pub type time_t = i64;
pub type c_long = i64;
pub type c_int = i32;
pub type clockid_t = c_int;

#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

pub const CLOCK_MONOTONIC: clockid_t = 1;
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 3;

extern "C" {
    pub fn clock_gettime(clk_id: clockid_t, tp: *mut timespec) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_clock_ticks_forward() {
        let mut a = timespec::default();
        let ra = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut a) };
        assert_eq!(ra, 0);
        // burn a little CPU so the clock must advance
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        assert!(acc != 1); // keep the loop observable
        let mut b = timespec::default();
        let rb = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut b) };
        assert_eq!(rb, 0);
        let na = a.tv_sec as i128 * 1_000_000_000 + a.tv_nsec as i128;
        let nb = b.tv_sec as i128 * 1_000_000_000 + b.tv_nsec as i128;
        assert!(nb >= na);
    }
}
