//! Offline stub of the `xla` (PJRT) bindings used by `dualip::runtime`.
//!
//! This testbed image has no XLA/PJRT shared library, so the accelerated
//! path cannot execute here; the repo's artifact-gated design already
//! self-skips every HLO test when `artifacts/manifest.txt` is absent. This
//! stub keeps the whole crate compiling and the CPU-reference + engine
//! layers fully functional: types and signatures match the real bindings,
//! host-side `Literal` plumbing works, and anything that would need the
//! PJRT runtime (HLO parsing, compilation, execution) returns a descriptive
//! error instead. Swap this path dependency for the real `xla` crate to
//! light up the accelerated path — no source changes needed.

use std::fmt;

/// Error type mirroring the real bindings' debug-printable error.
pub struct XlaError {
    pub message: String,
}

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError {
            message: format!(
                "{what} requires the PJRT runtime; this build uses the offline \
                 xla stub (rust/vendor/xla) — link the real xla crate to enable it"
            ),
        }
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.message)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types the host-side literal plumbing supports (f32 only here).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// Host-side literal: flat f32 buffer + dims. Fully functional in the stub
/// (the coordinator builds literals before every launch).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: data.iter().map(|v| v.to_f32()).collect(),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(XlaError {
                message: format!(
                    "reshape: {} elements into dims {dims:?}",
                    self.data.len()
                ),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(XlaError::unavailable("tuple literals from device buffers"))
    }
}

/// Parsed HLO module handle. Parsing HLO text needs the runtime.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable(&format!("parsing HLO text {path:?}")))
    }

    /// Parse HLO text held in memory — the registry-emission path
    /// (`BlockProjection::emit_hlo`) hands its module text here.
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        let head = text.lines().next().unwrap_or("").to_string();
        Err(XlaError::unavailable(&format!("parsing in-memory HLO text ({head:?})")))
    }
}

/// Computation wrapper (constructible; compilation is what fails).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("executable launch"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("device-to-host transfer"))
    }
}

/// PJRT client. Construction succeeds (so `dualip info` and artifact-less
/// paths keep working); compilation reports the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (offline xla shim; PJRT unavailable)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("XLA compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(HloModuleProto::from_text("HloModule slab_box_t4_w4\n").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.device_count() >= 1);
        assert!(client.platform_name().contains("stub"));
    }
}
